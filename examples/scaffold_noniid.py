"""QuAFL-SCAFFOLD (beyond-paper, paper §5 future work): controlled averaging
removes the non-iid client drift that slows vanilla QuAFL — the control
variates ride the same position-aware quantized exchange.

    PYTHONPATH=src python examples/scaffold_noniid.py
"""
import jax

from repro.configs.base import FedConfig
from repro.core import QuAFL, QuaflScaffold
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def main():
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    bf = lambda d, k: client_batch(k, d, 32)

    vanilla = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    scaffold = QuaflScaffold(fed=fed, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf)
    sv, sc = vanilla.init(params0), scaffold.init(params0)
    key = jax.random.PRNGKey(1)
    print("round |  vanilla acc | scaffold acc | ||c||")
    for r in range(1, 81):
        key, k1, k2 = jax.random.split(key, 3)
        sv, _ = vanilla.round(sv, part, k1)
        sc, m = scaffold.round(sc, part, k2)
        if r % 16 == 0:
            _, mv = mlp_loss(vanilla.eval_params(sv), test)
            _, ms = mlp_loss(scaffold.eval_params(sc), test)
            print(f"{r:5d} | {float(mv['acc']):12.3f} |"
                  f" {float(ms['acc']):12.3f} | {float(m['c_norm']):.3f}")
    print("\nSCAFFOLD pays 2x the (cheap, quantized) communication for the "
          "drift correction — both messages are b-bit lattice codes.")


if __name__ == "__main__":
    main()
