"""QuAFL-SCAFFOLD (beyond-paper, paper §5 future work): controlled averaging
removes the non-iid client drift that slows vanilla QuAFL — the control
variates ride the same position-aware quantized exchange. Both variants come
out of the algorithm registry and run under ``compare()`` with the same
seeds and budget.

    PYTHONPATH=src python examples/scaffold_noniid.py
"""
import jax

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import compare, make_algorithm
from repro.models.mlp import init_mlp_classifier, mlp_loss


def main():
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    bf = lambda d, k: client_batch(k, d, 32)

    algs = {name: make_algorithm(name, fed, loss_fn=mlp_loss,
                                 template=params0, batch_fn=bf)
            for name in ("quafl", "quafl_scaffold")}
    traces = compare(algs, params0, part, jax.random.PRNGKey(1),
                     rounds=80, eval_every=16,
                     eval_fn=lambda p: {"acc": float(mlp_loss(p, test)[1]
                                                     ["acc"])})

    print("round |  vanilla acc | scaffold acc | ||c||")
    rows = zip(traces["quafl"].rows, traces["quafl_scaffold"].rows)
    for rv, rs in rows:
        print(f"{rv['round']:5d} | {rv['acc']:12.3f} | {rs['acc']:12.3f} |"
              f" {rs['c_norm']:.3f}")
    print("\nSCAFFOLD pays 2x the (cheap, quantized) communication for the "
          "drift correction — both messages are b-bit lattice codes.")


if __name__ == "__main__":
    main()
