"""End-to-end driver: distributed QuAFL training of a ~100M-parameter LLaMA-
family model for a few hundred rounds on synthetic non-iid token streams,
with quantized client/server exchange — the (b) deliverable.

Default invocation trains a ~100M model for 200 rounds (CPU: ~20–40 min):

    PYTHONPATH=src python examples/train_e2e.py
Faster sanity pass:
    PYTHONPATH=src python examples/train_e2e.py --steps 20 --tiny
"""
import argparse
import time

import jax
import jax.numpy as jnp
from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.configs.base import FedConfig, LayerSpec, ShapeConfig
from repro.data.synthetic import lm_token_stream
from repro.launch.steps import build_train_step, init_train_state
from repro.models.model import lm_loss


def model_100m():
    """llama3.2-family member scaled to ~100M params."""
    return get_config("llama3.2-1b").replace(
        n_layers=4, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab_size=32_000,
        schedule=(LayerSpec(),),
        param_dtype="float32", dtype="float32")


def model_tiny():
    return get_config("llama3.2-1b").replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=1024, schedule=(LayerSpec(),),
        param_dtype="float32", dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    fed = FedConfig(n_clients=args.n_slots, s=args.n_slots,
                    local_steps=args.local_steps, lr=args.lr, bits=args.bits)
    shape = ShapeConfig("e2e", args.seq, args.batch * args.n_slots, "train")
    from repro.utils.compat import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    with mesh:
        step, _, _ = build_train_step(cfg, fed, mesh, shape,
                                      fed_mode="client_dp", remat=False)
        step = jax.jit(step, donate_argnums=(0,))
        state = init_train_state(cfg, key, args.n_slots)
        n_params = sum(int(v.size) for v in state.server.values())
        print(f"model: {cfg.name}-100m  params={n_params/1e6:.1f}M  "
              f"slots={args.n_slots} K={args.local_steps} bits={args.bits}")
        eval_toks = lm_token_stream(jax.random.PRNGKey(99), args.batch,
                                    args.seq, cfg.vocab_size)
        t0 = time.time()
        for r in range(args.steps):
            key, kd, kr = jax.random.split(key, 3)
            toks = jnp.stack([
                jnp.stack([lm_token_stream(
                    jax.random.fold_in(jax.random.fold_in(kd, i), q),
                    args.batch, args.seq, cfg.vocab_size, client_id=i)
                    for q in range(args.local_steps)])
                for i in range(args.n_slots)])
            state, m = step(state, {"tokens": toks}, jax.random.key_data(kr))
            if (r + 1) % max(args.steps // 10, 1) == 0 or r == 0:
                loss, _ = lm_loss(cfg, state.server, {"tokens": eval_toks})
                print(f"round {r+1:4d}/{args.steps} "
                      f"server_loss={float(loss):.4f} "
                      f"h={float(m['h_steps_mean']):.1f} "
                      f"({time.time()-t0:.0f}s)", flush=True)
        if args.checkpoint_dir:
            save_checkpoint(args.checkpoint_dir, args.steps, state.server)
            print("checkpoint:", args.checkpoint_dir)


if __name__ == "__main__":
    main()
