"""Serving example: batched request serving of a model from the assigned
zoo through the prefill + single-token-decode path (what the decode_32k /
long_500k dry-run shapes lower at production scale).

    PYTHONPATH=src python examples/serve_requests.py --arch gemma2-2b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_reduced, list_archs
from repro.models.model import init_lm
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b",
                    choices=[a for a in list_archs() if a != "paper-mlp"])
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    if cfg.encdec:
        raise SystemExit("pick a decoder-only arch for this demo")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=96, temperature=0.7)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        plen = int(rng.integers(4, 32))
        eng.submit(Request(prompt=rng.integers(1, cfg.vocab_size,
                                               plen).tolist(),
                           max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run(jax.random.PRNGKey(1))
    dt = time.time() - t0
    tok = sum(len(r.out_tokens) for r in done)
    print(f"{args.arch} (reduced): {len(done)} requests, {tok} tokens "
          f"in {dt:.2f}s -> {tok/dt:.1f} tok/s")
    for i, r in enumerate(done[:3]):
        print(f"  req{i}: {len(r.prompt)}-token prompt -> {r.out_tokens}")


if __name__ == "__main__":
    main()
