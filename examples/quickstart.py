"""Quickstart: the unified federated-algorithm API on a federated
classification task.

Every server variant in the repo — QuAFL (paper Alg. 1), FedAvg, FedBuff,
sequential, and the beyond-paper extensions — implements ONE protocol
(``init / round / eval_params``), so the paper's headline comparison is
three calls: build algorithms by name from the registry, hand them to
``compare()`` with an equal simulated-wall-clock budget, read the traces.
16 clients (30% slow), non-iid by-class split.

COMPRESSION is composable the same way: every algorithm takes ``uplink=``
/ ``downlink=`` codec specs from the ``repro.compression.codecs`` registry
— here QuAFL runs with (a) the default 8-bit lattice codec, (b) a
PER-CLIENT heterogeneous uplink (fast clients at b=8, the slow 30% packed
at b=4), and FedPAQ-style compressed FedAvg joins as just another registry
name.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import compare, make_algorithm
from repro.models.mlp import init_mlp_classifier, mlp_loss


def main():
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=8,
                    swt=10.0, quantizer="lattice")
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    bf = lambda d, k: client_batch(k, d, 32)

    mk = lambda name, **kw: make_algorithm(name, fed, loss_fn=mlp_loss,
                                           template=params0, batch_fn=bf,
                                           **kw)
    algs = {
        "quafl": mk("quafl"),
        # heterogeneous uplink: stragglers send 4-bit codes, fast clients 8
        "quafl_het": mk("quafl", uplink={"fast": "lattice",
                                         "slow": "lattice_packed:bits=4"}),
        "fedavg": mk("fedavg"),
        # FedPAQ-style compressed FedAvg: one registry name + one codec spec
        "fedpaq": mk("compressed_fedavg", uplink="scalar"),
    }

    # equal simulated wall-clock: ~120 QuAFL rounds' worth of time. FedAvg
    # fits far fewer rounds in it — its synchronous server waits for the
    # slowest sampled client every round.
    budget = 120 * (fed.swt + fed.sit)
    traces = compare(algs, params0, part, jax.random.PRNGKey(1),
                     until_sim_time=budget, eval_every=24,
                     eval_fn=lambda p: {"acc": float(mlp_loss(p, test)[1]
                                                    ["acc"])})

    print("algorithm | rounds |  sim t |   acc | bits up | bits down")
    for name, tr in traces.items():
        f = tr.final
        print(f"{name:9s} | {tr.rounds:6d} | {f['sim_time']:6.0f} |"
              f" {f['acc']:5.3f} | {f['bits_up_total']:7.3g} |"
              f" {f['bits_down_total']:9.3g}")

    h, q = traces["quafl_het"].final, traces["quafl"].final
    print(f"\nheterogeneous uplink (slow 30% at b=4) sends "
          f"{q['bits_up_total'] / h['bits_up_total']:.2f}x fewer uplink "
          f"bits than uniform b=8 at acc {h['acc']:.3f} vs {q['acc']:.3f}")

    q, a = traces["quafl"].final, traces["fedavg"].final
    qbits = q["bits_up_total"] + q["bits_down_total"]
    abits = a["bits_up_total"] + a["bits_down_total"]
    ratio = (abits / traces["fedavg"].rounds) / (qbits / traces["quafl"].rounds)
    print(f"\nQuAFL sends {ratio:.1f}x fewer bits per round than FedAvg at "
          f"the same simulated wall-clock budget")
    print(f"QuAFL slow-client zero-progress polls (last round): "
          f"{q['h_zero_frac']:.2f} — the algorithm tolerates them (paper §4)")


if __name__ == "__main__":
    main()
