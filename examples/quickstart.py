"""Quickstart: QuAFL (paper Alg. 1) on a federated classification task.

16 clients (30% slow), non-iid by-class split, both communication directions
lattice-quantized to 8 bits. Compare against synchronous FedAvg at equal
simulated wall-clock time.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import FedConfig
from repro.core import FedAvg, QuAFL
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def main():
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=8,
                    swt=10.0, quantizer="lattice")
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    bf = lambda d, k: client_batch(k, d, 32)

    quafl = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    fedavg = FedAvg(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    sq, sf = quafl.init(params0), fedavg.init(params0)
    key = jax.random.PRNGKey(1)

    print("round |      QuAFL acc (sim t) |  FedAvg acc (sim t)")
    for r in range(1, 121):
        key, k1, k2 = jax.random.split(key, 3)
        sq, m = quafl.round(sq, part, k1)
        if r % 8 == 0:  # FedAvg rounds are ~8x longer (waits for stragglers)
            sf, _ = fedavg.round(sf, part, k2)
        if r % 24 == 0:
            _, mq = mlp_loss(quafl.eval_params(sq), test)
            _, mf = mlp_loss(fedavg.eval_params(sf), test)
            print(f"{r:5d} | {float(mq['acc']):14.3f} ({float(sq.sim_time):5.0f})"
                  f" | {float(mf['acc']):10.3f} ({float(sf.sim_time):5.0f})")
    print(f"\nQuAFL bits sent: {float(sq.bits_sent):.3g} "
          f"(FedAvg: {float(sf.bits_sent):.3g}) — "
          f"{float(sf.bits_sent)/float(sq.bits_sent)*sq.t/sf.t:.1f}x fewer "
          f"bits per round")
    print(f"QuAFL slow-client zero-progress fraction this round: "
          f"{float(m['h_zero_frac']):.2f}")


if __name__ == "__main__":
    main()
