"""Client heterogeneity demo (paper §2.1 + Fig. 3): QuAFL with fast/slow
clients, weighted (η_i = H_min/H_i) vs unweighted dampening, and the
robustness headline — slow clients sometimes contribute ZERO local steps and
the algorithm still converges. Runs through the unified ``simulate()``
harness; the zero-progress fraction comes straight off the trace rows.

Heterogeneity also extends to the WIRE (repro.compression.codecs): a
``{"fast": ..., "slow": ...}`` uplink codec spec gives each speed class its
own bit budget — here the slow 30% upload 4-bit packed lattice codes while
fast clients keep 8 bits, one config knob instead of a code change.

And to WHO ANSWERS the poll (repro.fed.population): a participation spec
swaps the paper's uniform sampling for cyclic availability — only one
phase group of clients is reachable per window — at the SAME simulated
clock budget, measuring what periodic client availability costs in
accuracy with zero algorithm changes.

    PYTHONPATH=src python examples/heterogeneous_clients.py
"""
import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import client_speeds, expected_steps, make_algorithm, simulate
from repro.models.mlp import init_mlp_classifier, mlp_loss


def run(weighted: bool, swt: float, rounds: int = 120, uplink=None,
        bits: int = 10, participation: str = ""):
    fed = FedConfig(n_clients=20, s=5, local_steps=10, lr=0.3, bits=bits,
                    swt=swt, slow_frac=0.3, lam_slow=1 / 16, weighted=weighted,
                    participation=participation)
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=lambda d, k: client_batch(k, d, 32),
                         uplink=uplink)
    # record_every=1 traces every round's h_zero_frac; the test-set eval
    # runs ONCE, on the final round (eval_every=0 -> eval only at done)
    trace = simulate(alg, params0, part, jax.random.PRNGKey(1),
                     rounds=rounds, eval_every=0, record_every=1,
                     eval_fn=lambda p: {"acc": float(mlp_loss(p, test)[1]
                                                     ["acc"])})
    zero_frac = float(np.mean(trace.column("h_zero_frac")))
    return trace, zero_frac, alg


def main():
    fed = FedConfig(n_clients=20, slow_frac=0.3, lam_slow=1 / 16,
                    local_steps=10, swt=2.0)
    lam = client_speeds(fed, 20)
    H = expected_steps(fed, lam)
    print("client speeds λ:", np.unique(lam),
          " expected steps H_i:", np.unique(H.round(2)))
    for weighted in (False, True):
        tr, zf, alg = run(weighted, swt=2.0)
        print(f"weighted={weighted}:  acc={tr.final['acc']:.3f}  "
              f"zero-progress polls={zf:.1%}  η_i∈[{alg.eta_i.min():.2f},"
              f"{alg.eta_i.max():.2f}]")
    print("\n(paper §4: QuAFL tolerates a large fraction of slow clients "
          "submitting infrequent or even empty updates)")

    # --- heterogeneous bit budgets: slow clients at b=4, fast at b=8 ------
    tr_u, _, _ = run(False, swt=2.0, bits=8)
    tr_h, _, alg_h = run(False, swt=2.0, bits=8,
                         uplink={"fast": "lattice",
                                 "slow": "lattice_packed:bits=4"})
    bits_pc = np.asarray(alg_h.codec_up.bits_per_client)
    print(f"\nheterogeneous codecs: per-client uplink bits "
          f"{dict(zip(*np.unique(bits_pc, return_counts=True)))}")
    print(f"uniform b=8:      acc={tr_u.final['acc']:.3f}  "
          f"uplink bits={tr_u.final['bits_up_total']:.3g}")
    print(f"fast b=8/slow b=4: acc={tr_h.final['acc']:.3f}  "
          f"uplink bits={tr_h.final['bits_up_total']:.3g}  "
          f"({tr_u.final['bits_up_total'] / tr_h.final['bits_up_total']:.2f}"
          f"x fewer — stragglers answer on half the per-coordinate bit "
          f"budget)")

    # --- participation: cyclic availability vs uniform, equal clock -------
    # QuAFL rounds all cost swt+sit, so equal rounds IS equal sim-time; the
    # cyclic spec makes only one of 4 phase groups (5 of 20 clients)
    # reachable per 2-round window — the poll must take whoever is awake.
    tr_cyc, _, _ = run(False, swt=2.0, bits=8,
                       participation="cyclic:period=8,phase_groups=4")
    print(f"\nparticipation at equal sim-time "
          f"(sim_t={tr_u.final['sim_time']:.0f}s == "
          f"{tr_cyc.final['sim_time']:.0f}s):")
    print(f"uniform polling:      acc={tr_u.final['acc']:.3f}")
    print(f"cyclic availability:  acc={tr_cyc.final['acc']:.3f}  "
          f"(gap {tr_u.final['acc'] - tr_cyc.final['acc']:+.3f} — periodic "
          f"client availability is a config axis, not a code change)")


if __name__ == "__main__":
    main()
