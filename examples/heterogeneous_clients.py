"""Client heterogeneity demo (paper §2.1 + Fig. 3): QuAFL with fast/slow
clients, weighted (η_i = H_min/H_i) vs unweighted dampening, and the
robustness headline — slow clients sometimes contribute ZERO local steps and
the algorithm still converges.

    PYTHONPATH=src python examples/heterogeneous_clients.py
"""
import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.core import QuAFL, client_speeds, expected_steps
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def run(weighted: bool, swt: float, rounds: int = 120):
    fed = FedConfig(n_clients=20, s=5, local_steps=10, lr=0.3, bits=10,
                    swt=swt, slow_frac=0.3, lam_slow=1 / 16, weighted=weighted)
    part, test = make_federated_classification(0, fed.n_clients, d=32,
                                               n_classes=10, iid=False)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 32, 64, 10)
    alg = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0,
                batch_fn=lambda d, k: client_batch(k, d, 32))
    st = alg.init(params0)
    key = jax.random.PRNGKey(1)
    zero_frac = []
    for _ in range(rounds):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
        zero_frac.append(float(m["h_zero_frac"]))
    _, metr = mlp_loss(alg.eval_params(st), test)
    return float(metr["acc"]), float(np.mean(zero_frac)), alg


def main():
    fed = FedConfig(n_clients=20, slow_frac=0.3, lam_slow=1 / 16,
                    local_steps=10, swt=2.0)
    lam = client_speeds(fed, 20)
    H = expected_steps(fed, lam)
    print("client speeds λ:", np.unique(lam),
          " expected steps H_i:", np.unique(H.round(2)))
    for weighted in (False, True):
        acc, zf, alg = run(weighted, swt=2.0)
        print(f"weighted={weighted}:  acc={acc:.3f}  "
              f"zero-progress polls={zf:.1%}  η_i∈[{alg.eta_i.min():.2f},"
              f"{alg.eta_i.max():.2f}]")
    print("\n(paper §4: QuAFL tolerates a large fraction of slow clients "
          "submitting infrequent or even empty updates)")


if __name__ == "__main__":
    main()
