"""Distributed train/serve step correctness (single device + host-device
mesh subprocess) and sharding-rule unit tests."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_reduced
from repro.configs.base import FedConfig, ShapeConfig
from repro.launch.steps import build_train_step, init_train_state
from repro.sharding.rules import RULES_TP, RULES_FSDP, pspec_for


def _mesh11():
    from repro.utils.compat import make_mesh
    return make_mesh((1, 1), ("data", "model"))


def test_pspec_divisibility_fallback():
    mesh = _mesh11()
    # trivially divisible by 1
    assert pspec_for((40, 128), ("q_flat", None), RULES_TP, mesh) == P("model")

    import jax as _j
    mesh16 = None  # can't build 16x16 on 1 device; emulate via shape dict

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    fm = FakeMesh()
    # 40 heads do NOT divide 16 -> replicated; 5120 flattened q DOES
    assert pspec_for((40, 128), ("q_flat", None), RULES_TP, fm) == P()
    assert pspec_for((5120, 5120), ("embed", "q_flat"), RULES_TP, fm) == \
        P(None, "model")
    # same mesh axis never assigned twice
    assert pspec_for((16, 16), ("clients", "batch"), RULES_TP, fm) == \
        P("data")
    # FSDP shards embed over data
    assert pspec_for((1024, 4096), ("embed", "mlp"), RULES_FSDP, fm) == \
        P("data", "model")
    # batch=1 leaves data free for kv_seq (long_500k decode)
    assert pspec_for((1, 524288, 8, 128),
                     ("batch", "kv_seq", None, None), RULES_TP, fm) == \
        P(None, "data")


@pytest.mark.parametrize("transport", ["dequant_psum", "code_allgather"])
def test_train_step_transports_agree(transport):
    """Both transports must produce identical numerics (same codes/keys)."""
    cfg = get_reduced("llama3.2-1b")
    fed = FedConfig(local_steps=2, bits=8, lr=0.05)
    shape = ShapeConfig("tiny", 16, 4, "train")
    mesh = _mesh11()
    with mesh:
        step, _, _ = build_train_step(cfg, fed, mesh, shape,
                                      fed_mode="client_dp",
                                      transport=transport)
        st = init_train_state(cfg, jax.random.PRNGKey(0), 1)
        toks = jax.random.randint(jax.random.PRNGKey(1), (1, 2, 4, 16), 0,
                                  cfg.vocab_size)
        key = jax.random.key_data(jax.random.PRNGKey(2))
        st2, m = jax.jit(step)(st, {"tokens": toks}, key)
    assert np.isfinite(float(m["quant_err_sq"]))
    leaf = next(iter(st2.server.values()))
    assert not bool(jnp.isnan(leaf).any())
    # store for cross-transport comparison
    test_train_step_transports_agree.results = getattr(
        test_train_step_transports_agree, "results", {})
    test_train_step_transports_agree.results[transport] = st2.server


def test_transports_identical_results():
    res = getattr(test_train_step_transports_agree, "results", {})
    if len(res) == 2:
        a, b = res["dequant_psum"], res["code_allgather"]
        for k in a:
            np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                       atol=1e-6)


def test_train_step_mean_preservation_quantfree():
    """lr=0 + no quantization: server+clients mean is exactly preserved
    by the distributed step too."""
    cfg = get_reduced("olmo-1b")
    fed = FedConfig(local_steps=1, lr=0.0, quantizer="none")
    shape = ShapeConfig("tiny", 16, 4, "train")
    mesh = _mesh11()
    with mesh:
        step, _, _ = build_train_step(cfg, fed, mesh, shape,
                                      fed_mode="client_dp", quantized=False)
        st = init_train_state(cfg, jax.random.PRNGKey(0), 1)
        # diverge the client
        st = st._replace(clients={
            k: v + 0.1 * jax.random.normal(jax.random.PRNGKey(3), v.shape)
            for k, v in st.clients.items()})
        toks = jnp.zeros((1, 1, 4, 16), jnp.int32)
        key = jax.random.key_data(jax.random.PRNGKey(2))
        st2, _ = jax.jit(step)(st, {"tokens": toks}, key)
    for k in st.server:
        mu0 = (st.server[k] + jnp.sum(st.clients[k], 0)) / 2
        mu1 = (st2.server[k] + jnp.sum(st2.clients[k], 0)) / 2
        np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0),
                                   atol=1e-5)


SUBPROC = r"""
import dataclasses
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_reduced
from repro.configs.base import FedConfig, ShapeConfig
from repro.launch.steps import build_train_step, build_serve_step, \
    init_train_state
from repro.launch.specs import input_specs, abstract_cache
from repro.utils.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("llama3.2-1b").replace(n_heads=8, n_kv_heads=2)
fed = FedConfig(local_steps=2, lr=0.05, bits=8)
shape = ShapeConfig("tiny", 16, 8, "train")
with mesh:
    step, spec, sh = build_train_step(cfg, fed, mesh, shape,
                                      fed_mode="client_dp")
    st = init_train_state(cfg, jax.random.PRNGKey(0), 4)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 2, 16), 0,
                              cfg.vocab_size)
    key = jax.random.key_data(jax.random.PRNGKey(2))
    fn = jax.jit(step, in_shardings=sh)
    st2, m = fn(st, {"tokens": toks}, key)
    assert not bool(jnp.isnan(st2.server["embed/tok"]).any())
    # shard_map x Pallas composition: every shard_map client-sum transport
    # (fp32 psum / packed-code all-gather / the fused scatter-resident
    # reduce_scatter) through the interpreted Pallas kernels must agree
    # with the jnp backend PER TRANSPORT (ROADMAP: validate
    # pallas_interpret under shard_map before the real-TPU promotion)
    servers = {}
    for tr in ("shard_local", "shard_local_codes", "shard_local_rs"):
        for kb in ("jnp", "pallas_interpret"):
            fed_kb = dataclasses.replace(fed, kernel_backend=kb)
            step_kb, _, sh_kb = build_train_step(cfg, fed_kb, mesh, shape,
                                                 fed_mode="client_dp",
                                                 transport=tr)
            st_kb, m_kb = jax.jit(step_kb, in_shardings=sh_kb)(
                st, {"tokens": toks}, key)
            assert np.isfinite(float(m_kb["quant_err_sq"])), (tr, kb)
            servers[tr, kb] = jax.device_get(st_kb.server)
        for k in servers[tr, "jnp"]:
            np.testing.assert_allclose(
                np.asarray(servers[tr, "jnp"][k], np.float32),
                np.asarray(servers[tr, "pallas_interpret"][k], np.float32),
                rtol=2e-5, atol=2e-5, err_msg=f"{tr}:{k}")
    # code_allgather moves different bytes but computes the SAME aggregate
    # as the fp32 psum
    for k in servers["shard_local", "jnp"]:
        np.testing.assert_allclose(
            np.asarray(servers["shard_local_codes", "jnp"][k], np.float32),
            np.asarray(servers["shard_local", "jnp"][k], np.float32),
            rtol=2e-5, atol=2e-5, err_msg=k)
    # the fused reduce_scatter re-quantizes the redistribution at the
    # downlink wire width (the per-client lattices share no common grid, so
    # a coded re-gather cannot be exact): bounded drift, not bit-equality.
    # A wrap failure would show O(1) per-leaf error; honest stochastic
    # rounding stays well under 25% even on the tiny LN-scale leaves whose
    # subgaussian coord bound is loosest, and under 2% model-wide.
    num = den = 0.0
    for k in servers["shard_local", "jnp"]:
        a = np.asarray(servers["shard_local_rs", "jnp"][k], np.float32)
        b = np.asarray(servers["shard_local", "jnp"][k], np.float32)
        rel = np.linalg.norm(a - b) / (np.linalg.norm(b) + 1e-9)
        assert rel < 0.25, (k, rel)
        num += float(np.sum((a - b) ** 2)); den += float(np.sum(b ** 2))
    assert (num / den) ** 0.5 < 0.02, (num / den) ** 0.5
    # serve step lowers + compiles on the same mesh
    sshape = ShapeConfig("d", 64, 8, "decode")
    sstep, p_spec, c_spec, ssh = build_serve_step(cfg, mesh, sshape)
    ins = input_specs(cfg, sshape)
    jax.jit(sstep, in_shardings=ssh).lower(
        p_spec, c_spec, ins["token"], ins["pos"]).compile()
print("SUBPROC_OK")
"""


def test_sharded_train_and_serve_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "SUBPROC_OK" in r.stdout, r.stdout + r.stderr
