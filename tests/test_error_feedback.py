"""Model-tracking ablation (paper §2.2): a server tracks a drifting model
x_t from quantized client messages.

 * lattice — position-aware: client sends Enc(x_t), server decodes against
   its own estimate; NO client memory.
 * qsgd-delta — client sends Q(x_t − x̂_{t−1}); unbiased but error compounds.
 * qsgd + error feedback — needs a d-sized client accumulator.

The paper's claim: lattice matches EF's tracking quality without the memory.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import LatticeQuantizer, QSGDQuantizer
from repro.compression.error_feedback import ErrorFeedbackQSGD


def _drift(key, d, steps, scale=0.05):
    xs = [jax.random.normal(key, (d,))]
    for i in range(steps):
        xs.append(xs[-1] + scale * jax.random.normal(
            jax.random.fold_in(key, i), (d,)))
    return xs


def run_tracking(d=4096, steps=30, bits=6, seed=0):
    key = jax.random.PRNGKey(seed)
    xs = _drift(key, d, steps)
    lat = LatticeQuantizer(bits=bits)
    qsg = QSGDQuantizer(bits=bits)
    ef = ErrorFeedbackQSGD(bits=bits)

    est_lat = xs[0]
    est_del = xs[0]
    est_ef = xs[0]
    st = ef.init(d)
    errs = {"lattice": [], "qsgd_delta": [], "qsgd_ef": []}
    for t in range(1, steps + 1):
        k = jax.random.fold_in(key, 1000 + t)
        x = xs[t]
        # lattice: encode x, decode vs server estimate (no client state)
        msg = lat.encode(k, x, jnp.linalg.norm(x - est_lat) + 1e-8)
        est_lat = lat.decode(k, msg, est_lat)
        # qsgd on the delta
        est_del = est_del + qsg.decode(k, qsg.encode(k, x - est_del))
        # qsgd + EF
        _, dec, st = ef.compress(k, x - est_ef, st)
        est_ef = est_ef + dec
        nx = float(jnp.linalg.norm(x))
        errs["lattice"].append(float(jnp.linalg.norm(est_lat - x)) / nx)
        errs["qsgd_delta"].append(float(jnp.linalg.norm(est_del - x)) / nx)
        errs["qsgd_ef"].append(float(jnp.linalg.norm(est_ef - x)) / nx)
    return {k: float(np.mean(v[-10:])) for k, v in errs.items()}


def test_lattice_tracks_without_memory():
    errs = run_tracking()
    # every scheme must actually track (no divergence)
    assert errs["qsgd_ef"] < 0.5, errs
    # lattice stays accurate and is competitive with EF (which needs a
    # d-sized client accumulator); both beat plain delta-QSGD or tie
    assert errs["lattice"] < 0.05, errs
    assert errs["lattice"] < 1.5 * errs["qsgd_ef"], errs
    assert errs["lattice"] <= errs["qsgd_delta"] * 1.5, errs


def test_ef_accumulator_is_the_memory_cost():
    ef = ErrorFeedbackQSGD(bits=8)
    st = ef.init(1000)
    assert st.error.shape == (1000,)  # the client memory the paper avoids
