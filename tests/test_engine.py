"""Device-resident round engine: ring buffer, scanned chunks, seed bridge.

Pins the multi_layer_refactor four ways:

  * the device :class:`RingBuffer`'s masked-min pop/push order is
    bit-for-bit the python heap's (``fed.clock.ArrivalQueue``) over
    randomized event streams, ties included,
  * the scanned engine (``simulate(..., scan_chunk=K)``) is bit-for-bit the
    eager loop for every device_round-capable algorithm — params, rows, and
    cumulative bit counters — for quafl, fedavg, fedbuff (device), the
    sequential baseline, and scaffold,
  * the device-resident FedBuff consuming the legacy numpy draws through
    the seed bridge reproduces the python event simulation: identical event
    times/order and float-rounding-level identical model iterates,
  * chunk-boundary budget semantics and the chunked adaptive walk behave as
    documented, and the ``--only algorithms`` bench driver still runs
    (perf_smoke gate).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import (ArrivalQueue, make_algorithm, ring_init, ring_peek,
                       ring_pop, ring_push, ring_size, simulate,
                       supports_scan)
from repro.fed.engine import RoundEngine, fedbuff_completion_table
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.utils.tree import tree_flatten_vector

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st


def _setup(fed, seed=0, iid=True, d=16, hidden=32, classes=4):
    part, test = make_federated_classification(seed, fed.n_clients, d=d,
                                               n_classes=classes, iid=iid)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), d, hidden,
                                     classes)
    bf = lambda dd, k: client_batch(k, dd, d)
    return part, test, params0, bf


# ---------------------------------------------------------------------------
# RingBuffer vs ArrivalQueue: pop/push order pinned bit-for-bit
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_ring_buffer_matches_arrival_queue(seed):
    """Randomized interleaved push/pop streams (duplicate times included to
    exercise the lexicographic (time, client) tie-break): the device
    masked-min pop returns EXACTLY the heap's (t, client) sequence."""
    rng = np.random.default_rng(seed)
    cap = int(rng.integers(2, 9))
    rb, q = ring_init(cap), ArrivalQueue()
    n_live = 0
    for _ in range(60):
        do_push = n_live == 0 or (n_live < cap and rng.random() < 0.6)
        if do_push:
            # float32 times from a small grid so exact ties happen often
            t = np.float32(rng.integers(0, 6) + rng.choice([0.0, 0.5]))
            c = int(rng.integers(0, 5))
            rb = ring_push(rb, t, c)
            q.push(float(t), c)
            n_live += 1
        else:
            tp, cp = ring_peek(rb)
            rb, t, c = ring_pop(rb)
            th, ch = q.pop()
            assert (float(t), int(c)) == (float(th), int(ch))
            assert (float(tp), int(cp)) == (float(th), int(ch))
            n_live -= 1
        assert int(ring_size(rb)) == n_live == len(q)


def test_ring_buffer_ops_trace_under_jit():
    """The queue ops are pure pytree functions: jit-able and scan-able."""
    rb = ring_init(3)
    rb = jax.jit(ring_push)(rb, 2.0, 1)
    rb = jax.jit(ring_push)(rb, 1.0, 2)
    rb, t, c = jax.jit(ring_pop)(rb)
    assert (float(t), int(c)) == (1.0, 2)
    assert int(ring_size(rb)) == 1


# ---------------------------------------------------------------------------
# scanned engine == eager loop, bit-for-bit
# ---------------------------------------------------------------------------

SCAN_NAMES = ("quafl", "fedavg", "fedbuff_device", "sequential",
              "quafl_scaffold")


@pytest.mark.parametrize("name", SCAN_NAMES)
def test_scanned_engine_matches_eager_bitwise(name):
    """rounds=5 with scan_chunk=2 (chunk lengths 2,2,1), dense rows, eval
    cadence 2: final params, every row's schema keys, the eval results, and
    the cumulative bit counters must all be EXACTLY the eager loop's."""
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=8,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    kw = {"buffer_size": 3} if name == "fedbuff_device" else {}
    alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, **kw)
    assert supports_scan(alg)
    eval_fn = lambda p: {"loss": float(mlp_loss(p, test)[0])}
    run = lambda chunk: simulate(alg, params0, part, jax.random.PRNGKey(3),
                                 rounds=5, eval_every=2, record_every=1,
                                 eval_fn=eval_fn, scan_chunk=chunk)
    tre, trs = run(0), run(2)
    assert tre.engine == "eager" and trs.engine == "scanned"
    fe = np.asarray(tree_flatten_vector(alg.eval_params(tre.final_state)))
    fs = np.asarray(tree_flatten_vector(alg.eval_params(trs.final_state)))
    np.testing.assert_array_equal(fe, fs)
    assert len(tre.rows) == len(trs.rows) == 5
    for re, rs in zip(tre.rows, trs.rows):
        assert re["round"] == rs["round"]
        assert re.get("loss") == rs.get("loss")   # eval rows land identically
        for k in ("sim_time", "round_time", "bits_up", "bits_down",
                  "h_steps_mean", "quant_err", "bits_up_total",
                  "bits_down_total"):
            assert re[k] == rs[k], (name, re["round"], k)


def test_scanned_lattice_quafl_matches_eager():
    """The full rotated-space lattice pipeline under the scanned engine.

    A single-round chunk is bit-identical to the eager round; at chunk
    length >= 2 XLA compiles the loop body with different fusion choices
    than the standalone program and the rotation-heavy kernels accumulate
    <= 1-ulp float32 differences — so multi-round chunks are pinned at
    float32-rounding tolerance (the uncompressed/qsgd paths in
    test_scanned_engine_matches_eager_bitwise stay exact)."""
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.3, bits=8)
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    run = lambda chunk, rounds: simulate(
        alg, params0, part, jax.random.PRNGKey(5), rounds=rounds,
        eval_every=0, scan_chunk=chunk)
    # chunk length 1 materializes every round: bit-identical to eager
    np.testing.assert_array_equal(
        np.asarray(run(0, 1).final_state.server),
        np.asarray(run(2, 1).final_state.server))
    tre, trs = run(0, 4), run(4, 4)
    a, b = np.asarray(tre.final_state.server), \
        np.asarray(trs.final_state.server)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=5e-7)


def test_scan_chunk_falls_back_for_host_control_algorithms():
    """python FedBuff has no device_round: scan_chunk must silently run the
    eager engine (and still satisfy the budget semantics)."""
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("fedbuff", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, buffer_size=2)
    assert not supports_scan(alg)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=3,
                  eval_every=0, scan_chunk=4)
    assert tr.engine == "eager" and tr.rounds == 3


def test_round_engine_rejects_host_control_algorithms():
    fed = FedConfig(n_clients=4, s=2, local_steps=1, quantizer="qsgd")
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("fedbuff", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    with pytest.raises(TypeError):
        RoundEngine(alg)


def test_scan_budget_checked_at_chunk_boundaries():
    """until_sim_time under the scanned engine stops at the first CHUNK
    boundary past the budget — rounds are a multiple of the chunk length
    and the budget is exceeded, never undershot."""
    fed = FedConfig(n_clients=6, s=3, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    # quafl rounds last swt+sit=11s: budget 50s -> eager stops at round 5,
    # scanned (chunks of 4) at the round-8 boundary
    tre = simulate(alg, params0, part, jax.random.PRNGKey(1),
                   until_sim_time=50.0)
    trs = simulate(alg, params0, part, jax.random.PRNGKey(1),
                   until_sim_time=50.0, scan_chunk=4)
    assert tre.rounds == 5 and trs.rounds == 8
    assert trs.final["sim_time"] >= 50.0


def test_adaptive_chunked_walk():
    """The adaptive controller scans via scan_rounds: bits held constant
    inside a chunk, one walk per chunk, trace/bounds preserved."""
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=12)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("adaptive_quafl", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf, b_min=4, b_max=12)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(3), rounds=9,
                  eval_every=0, scan_chunk=3)
    assert tr.engine == "scanned" and tr.rounds == 9
    trace = tr.final_state.trace
    assert len(trace) == 9
    assert all(4 <= b <= 12 for b in trace)
    # within-chunk bits are constant (the walk reacts at boundaries only)
    assert trace[0] == trace[1] == trace[2] == 12
    # lattice at b=12 has tiny error -> the chunk walk must move DOWN
    assert trace[-1] < 12


# ---------------------------------------------------------------------------
# device-resident FedBuff: the seed bridge pins it to the python events
# ---------------------------------------------------------------------------

def test_fedbuff_device_bridge_matches_python_fedbuff():
    """With the completion table replaying the legacy numpy draws, the
    device formulation walks the SAME event sequence as the python heap
    implementation: event times bit-for-bit, bit counters exact, model
    iterates equal to float32 rounding (the python class applies its
    updates op-by-op, the fused round may contract them into FMAs)."""
    fed = FedConfig(n_clients=5, s=3, local_steps=2, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed, seed=1)
    key = jax.random.PRNGKey(11)
    rounds, Z = 6, 3
    py = make_algorithm("fedbuff", fed, loss_fn=mlp_loss, template=params0,
                        batch_fn=bf, buffer_size=Z, server_lr=0.5)
    table = fedbuff_completion_table(key, py.lam, fed.local_steps,
                                     n_events=Z * rounds + 2)
    dev = make_algorithm("fedbuff_device", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf, buffer_size=Z,
                         server_lr=0.5, completion_table=table)
    sp, sd = py.init(params0), dev.init(params0)
    for _ in range(rounds):
        sp, mp = py.round(sp, part, key)
        sd, md = dev.round(sd, part, key)
        # same event ORDER and draws through the bridge; the device clock
        # accumulates event times in float32 (python sums in float64)
        np.testing.assert_allclose(float(md["sim_time"]),
                                   float(mp["sim_time"]), rtol=1e-6)
        assert float(mp["bits_up"]) == float(md["bits_up"])
        assert float(mp["bits_down"]) == float(md["bits_down"])
    np.testing.assert_allclose(np.asarray(sp.server), np.asarray(sd.server),
                               rtol=1e-5, atol=1e-6)


def test_fedbuff_device_quantized_roundtrip():
    """Quantized deltas ride the device round too (qsgd + lattice), with a
    finite quant_err metric and the legacy per-flush bit accounting."""
    for quantizer in ("qsgd", "lattice"):
        fed = FedConfig(n_clients=4, s=2, local_steps=1, bits=8)
        part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
        alg = make_algorithm("fedbuff_device", fed, loss_fn=mlp_loss,
                             template=params0, batch_fn=bf, buffer_size=2,
                             quantize=True, quantizer=quantizer)
        st1, m = alg.round(alg.init(params0), part, jax.random.PRNGKey(2))
        assert float(m["bits_up"]) == 2 * alg.quant.message_bits(alg.d)
        assert float(m["bits_down"]) == 2 * alg.d * 32
        assert np.isfinite(float(m["quant_err"]))
        assert float(m["quant_err"]) > 0.0
        assert np.all(np.isfinite(np.asarray(st1.server)))


def test_fedbuff_device_exhausted_bridge_table_is_loud():
    """Simulating past the bridge table's replayed events must poison the
    clock with NaN (a silently clamped gather would quietly de-pin the
    event stream from the legacy draws)."""
    fed = FedConfig(n_clients=3, s=2, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    key = jax.random.PRNGKey(0)
    lam = np.full(3, fed.lam_fast, np.float32)
    table = fedbuff_completion_table(key, lam, fed.local_steps, n_events=1)
    alg = make_algorithm("fedbuff_device", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf, buffer_size=2,
                         completion_table=table)
    st = alg.init(params0)
    for _ in range(4):   # 8 completions >> the 1 replayed redraw
        st, m = alg.round(st, part, key)
    assert np.isnan(float(st.sim_time))


def test_fedbuff_device_unseeded_draws_are_deterministic():
    """Without a bridge table the durations come from the device stream:
    same init + same round keys -> identical trajectories."""
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("fedbuff_device", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf, buffer_size=2)
    runs = []
    for _ in range(2):
        st = alg.init(params0)
        for r in range(3):
            st, m = alg.round(st, part, jax.random.PRNGKey(4))
        runs.append((np.asarray(st.server), float(st.sim_time)))
    np.testing.assert_array_equal(runs[0][0], runs[1][0])
    assert runs[0][1] == runs[1][1]


# ---------------------------------------------------------------------------
# spmd through the registry + simulate()
# ---------------------------------------------------------------------------

def test_spmd_registry_simulates_with_standard_schema():
    """--algo spmd semantics: the mesh train step behind the protocol emits
    standardized Trace rows through simulate(), and the scanned engine
    reproduces the eager run bit-for-bit."""
    from repro.configs import get_reduced
    from repro.data.synthetic import federated_token_task
    from repro.fed.api import METRIC_KEYS

    cfg = get_reduced("llama3.2-1b")
    fed = FedConfig(n_clients=1, s=1, local_steps=2, lr=0.05, bits=8)
    from repro.models.model import init_lm
    params0, _ = init_lm(cfg, jax.random.PRNGKey(0))
    data, bf = federated_token_task(0, 1, 8, 2, 16, cfg.vocab_size)
    alg = make_algorithm("spmd", fed, loss_fn=None, template=params0,
                         batch_fn=bf, cfg=cfg, batch=2, seq=16)
    run = lambda chunk: simulate(alg, params0, data, jax.random.PRNGKey(1),
                                 rounds=2, eval_every=0, record_every=1,
                                 scan_chunk=chunk)
    tre, trs = run(0), run(2)
    for row in tre.rows:
        for k in METRIC_KEYS:
            assert k in row and np.isfinite(row[k]), (k, row)
        assert row["bits_up"] > 0 and row["quant_err"] > 0
    assert tre.rows[1]["sim_time"] == 2 * (fed.swt + fed.sit)
    pe, ps = tre.final_state.train.server, trs.final_state.train.server
    for k in pe:
        np.testing.assert_array_equal(np.asarray(pe[k]), np.asarray(ps[k]))


def test_spmd_requires_model_config():
    fed = FedConfig(n_clients=2, s=2, local_steps=1)
    with pytest.raises(ValueError):
        make_algorithm("spmd", fed, loss_fn=None, template={},
                       batch_fn=None)


# ---------------------------------------------------------------------------
# CI gate: the algorithms bench driver must keep running end to end
# ---------------------------------------------------------------------------

@pytest.mark.perf_smoke
def test_perf_smoke_bench_algorithms_quick():
    """Smoke-invoke ``python -m benchmarks.run --only algorithms --quick``
    so the bench driver can't silently rot. Quick output is routed to the
    gitignored bench_out/, so the committed baselines stay untouched."""
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "algorithms",
         "--quick"], cwd=root, env=env, capture_output=True, text=True,
        timeout=1200)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert "alg_quafl," in r.stdout
    assert "alg_scan_quafl," in r.stdout
    assert "ERROR" not in r.stdout, r.stdout[-2000:]
    out = os.path.join(root, "bench_out", "BENCH_algorithms.quick.json")
    assert os.path.exists(out)
