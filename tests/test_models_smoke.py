"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each family runs one forward/train step on CPU — shapes + no NaNs — plus
decode-vs-full-forward equivalence, the strongest cache-correctness check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, get_reduced, list_archs
from repro.models import decode_step, forward, init_cache, init_lm, lm_loss

ARCHS = [a for a in list_archs() if a != "paper-mlp"]


def _batch(cfg, key, b=2, t=32, enc_len=16):
    batch = {"tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size)}
    if cfg.encdec:
        batch["frontend"] = jax.random.normal(key, (b, enc_len, cfg.d_model))
    elif cfg.frontend:
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_constraints(arch):
    cfg = get_reduced(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    expect = {
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "mamba2-370m": (48, 1024, 32, 0, 0, 50280),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expect, (got, expect)
    # schedule consistency
    assert cfg.n_periods * len(cfg.schedule) + len(cfg.prefix) == cfg.n_layers


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one SGD train step on the reduced family member."""
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(0)
    params, _ = init_lm(cfg, key)
    batch = _batch(cfg, key)
    logits, _, aux = forward(cfg, params, batch)
    t_text = batch["tokens"].shape[1]
    assert logits.shape == (2, t_text, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())

    (loss, _), grads = jax.value_and_grad(
        lambda p: lm_loss(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in grads.values())
    assert np.isfinite(gn) and gn > 0
    new = {k: params[k] - 0.01 * grads[k] for k in params}
    loss2, _ = lm_loss(cfg, new, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = get_reduced(arch)
    key = jax.random.PRNGKey(1)
    params, _ = init_lm(cfg, key)
    b, t, t0 = 2, 24, 16
    batch = _batch(cfg, key, b=b, t=t)
    logits_full, _, _ = forward(cfg, params, batch)
    enc_len = 16 if cfg.encdec else 0
    cache = init_cache(cfg, b, max_seq=t + 16, enc_len=enc_len)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :t0]
    logits_pre, cache, _ = forward(cfg, params, pre, cache=cache, write_pos=0)
    outs = [logits_pre[:, -1]]
    off = cfg.n_frontend_tokens if (cfg.frontend and not cfg.encdec) else 0
    for pos in range(t0, t):
        lg, cache = decode_step(cfg, params, batch["tokens"][:, pos:pos + 1],
                                jnp.int32(pos + off), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(logits_full[:, t0 - 1:t]),
                               atol=2e-4, rtol=2e-3)


def test_long_variant_schedule():
    cfg = get_config("olmo-1b").with_long_variant()
    assert all(s.attn == "sliding" and s.window == 8192
               for s in cfg.schedule)
    # archs without a window variant are unchanged
    cfg2 = get_config("llava-next-34b").with_long_variant()
    assert all(s.attn == "full" for s in cfg2.schedule)


def test_sliding_ring_cache_decode():
    """Decode beyond the window with a ring cache == full forward."""
    cfg = get_reduced("gemma2-2b")  # has a sliding layer (window 64 reduced)
    key = jax.random.PRNGKey(2)
    params, _ = init_lm(cfg, key)
    b, t = 1, 96  # > window 64
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab_size)
    logits_full, _, _ = forward(cfg, params, {"tokens": toks})
    cache = init_cache(cfg, b, max_seq=t)
    t0 = 80
    _, cache, _ = forward(cfg, params, {"tokens": toks[:, :t0]}, cache=cache)
    outs = []
    for pos in range(t0, t):
        lg, cache = decode_step(cfg, params, toks[:, pos:pos + 1],
                                jnp.int32(pos), cache)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(logits_full[:, t0:t]),
                               atol=2e-4, rtol=2e-3)


def test_moe_dense_vs_ragged_impl():
    """The two MoE implementations agree when capacity is ample."""
    import dataclasses
    cfg = get_reduced("llama4-scout-17b-a16e")
    key = jax.random.PRNGKey(3)
    params, _ = init_lm(cfg, key)
    batch = _batch(cfg, key)
    lr, _, _ = forward(cfg, params, batch)
    cfg_d = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="dense",
                                                capacity_factor=8.0))
    ld, _, _ = forward(cfg_d, params, batch)
    np.testing.assert_allclose(np.asarray(lr), np.asarray(ld), atol=2e-4,
                               rtol=2e-3)
