"""Structural invariants of the model substrate (hypothesis-driven)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_reduced
from repro.configs.base import LayerSpec, MambaConfig
from repro.models import forward, init_lm
from repro.models.attention import attention_prefill
from repro.models.mamba import _ssd_chunked


@settings(deadline=None, max_examples=8)
@given(chunk=st.sampled_from([8, 16, 32, 64]), seed=st.integers(0, 50))
def test_ssd_chunk_size_invariance(chunk, seed):
    """The SSD dual form must be exact for ANY chunk length (the chunking is
    an implementation detail, not an approximation)."""
    key = jax.random.PRNGKey(seed)
    b, t, h, p, n = 1, 64, 2, 4, 8
    ks = jax.random.split(key, 4)
    xh = jax.random.normal(ks[0], (b, t, h, p))
    # small dt keeps the fp32 decay-product reassociation error well below
    # the tolerance (the identity is exact in real arithmetic; different
    # chunkings reassociate exp-cumsum products differently)
    dt = 0.3 * jax.nn.softplus(jax.random.normal(ks[1], (b, t, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, t, 1, n)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 9), (b, t, 1, n)) * 0.3
    y_ref, s_ref = _ssd_chunked(xh, dt, A, B, C, chunk=t)   # single chunk
    y, s = _ssd_chunked(xh, dt, A, B, C, chunk=chunk)
    scale = float(jnp.max(jnp.abs(y_ref))) + 1.0
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3 * scale, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=2e-3, rtol=5e-3)


@settings(deadline=None, max_examples=6)
@given(t=st.sampled_from([256, 512]), window=st.sampled_from([0, 128]),
       seed=st.integers(0, 20))
def test_attention_query_chunk_invariance(t, window, seed):
    """The query-chunked scan path must equal the one-shot sdpa path."""
    cfg = get_reduced("llama3.2-1b")
    spec = (LayerSpec(attn="sliding", window=window) if window
            else LayerSpec())
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, t, 4, 16))
    k = jax.random.normal(ks[1], (1, t, 2, 16))
    v = jax.random.normal(ks[2], (1, t, 2, 16))
    chunked = attention_prefill(cfg, spec, q, k, v)  # t triggers the scan

    # one-shot reference via masked sdpa
    from repro.models.attention import sdpa
    pos = jnp.arange(t)
    mask = pos[:, None] >= pos[None, :]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    ref = sdpa(q, k, v, mask, 1.0 / np.sqrt(16), 0.0)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(ref),
                               atol=2e-5)


def test_forward_deterministic():
    cfg = get_reduced("gemma3-12b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    a, _, _ = forward(cfg, params, {"tokens": toks})
    b, _, _ = forward(cfg, params, {"tokens": toks})
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_forward_batch_independence():
    """Per-sequence outputs must not depend on batch companions."""
    cfg = get_reduced("olmo-1b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 24), 0,
                              cfg.vocab_size)
    full, _, _ = forward(cfg, params, {"tokens": toks})
    solo, _, _ = forward(cfg, params, {"tokens": toks[1:2]})
    np.testing.assert_allclose(np.asarray(full[1]), np.asarray(solo[0]),
                               atol=2e-4, rtol=2e-3)


def test_mamba_reduced_chunk_matches_decode_state():
    """Prefill final SSM state == state after token-by-token decode."""
    from repro.models import decode_step, init_cache
    cfg = get_reduced("mamba2-370m")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0,
                              cfg.vocab_size)
    cache_a = init_cache(cfg, 1, max_seq=32)
    _, cache_a, _ = forward(cfg, params, {"tokens": toks}, cache=cache_a)
    cache_b = init_cache(cfg, 1, max_seq=32)
    _, cache_b, _ = forward(cfg, params, {"tokens": toks[:, :1]},
                            cache=cache_b)
    for pos in range(1, 16):
        _, cache_b = decode_step(cfg, params, toks[:, pos:pos + 1],
                                 jnp.int32(pos), cache_b)
    for k in cache_a:
        if k.endswith("ssm"):
            np.testing.assert_allclose(np.asarray(cache_a[k]),
                                       np.asarray(cache_b[k]), atol=1e-3,
                                       rtol=1e-2)


def test_vocab_logits_shape_all_archs_tied_and_untied():
    for arch in ("gemma2-2b", "deepseek-v2-236b"):
        cfg = get_reduced(arch)
        params, _ = init_lm(cfg, jax.random.PRNGKey(0))
        toks = jnp.zeros((1, 8), jnp.int32)
        lg, _, _ = forward(cfg, params, {"tokens": toks})
        assert lg.shape == (1, 8, cfg.vocab_size)
