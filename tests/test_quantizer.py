"""Quantizer + rotation properties (paper Lemma 3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.compression import (LatticeQuantizer, QSGDQuantizer, rotate,
                               make_quantizer, pad_len)


# --------------------------------------------------------------------------
# rotation: orthonormal, involutive (up to signs), deterministic in key
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=20)
@given(d=st.integers(8, 5000), seed=st.integers(0, 2**31 - 1))
def test_rotation_norm_preserving(d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    y = rotate(x, key)
    assert y.shape[0] == pad_len(d)
    np.testing.assert_allclose(float(jnp.linalg.norm(y)),
                               float(jnp.linalg.norm(x)), rtol=1e-4)


@settings(deadline=None, max_examples=20)
@given(d=st.integers(8, 5000), seed=st.integers(0, 2**31 - 1))
def test_rotation_inverse(d, seed):
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    xr = rotate(rotate(x, key), key, inverse=True)[:d]
    np.testing.assert_allclose(np.asarray(xr), np.asarray(x), atol=1e-4)


# --------------------------------------------------------------------------
# lattice quantizer: Lemma 3.1 properties
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=15)
@given(bits=st.integers(6, 12), dist=st.floats(1e-3, 10.0),
       seed=st.integers(0, 1000))
def test_lattice_error_proportional_to_distance(bits, dist, seed):
    """Property 2: ‖Q(x) − x‖ ≤ C(b)·‖x − y‖, independent of ‖x‖."""
    d = 4097
    q = LatticeQuantizer(bits=bits)
    key = jax.random.PRNGKey(seed)
    ref = jax.random.normal(key, (d,)) * 100.0  # large-norm reference
    delta = jax.random.normal(jax.random.fold_in(key, 1), (d,))
    x = ref + delta * (dist / float(jnp.linalg.norm(delta)))
    msg = q.encode(key, x, jnp.float32(dist))
    xh = q.decode(key, msg, ref)
    err = float(jnp.linalg.norm(xh - x))
    # γ·sqrt(d_pad) bound (γ from the message: includes the precision floor)
    bound = float(msg.gamma) * np.sqrt(pad_len(d))
    assert err <= bound * 1.01, (err, bound)
    # error scales with the DISTANCE (plus the fp32 floor of the model norm),
    # not with the 100x larger reference norm itself
    norm_floor = 100.0 * np.sqrt(d) * 2.0 ** -18 * np.sqrt(pad_len(d))
    assert err <= 2.0 * dist + norm_floor


def test_lattice_unbiased():
    """Property 1: E[Dec(y, Enc(x))] = x (stochastic rounding)."""
    d, N = 2000, 300
    q = LatticeQuantizer(bits=6)
    key = jax.random.PRNGKey(0)
    ref = jax.random.normal(key, (d,)) * 5
    x = ref + 0.1 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    dist = jnp.linalg.norm(x - ref)

    def one(i):
        k = jax.random.fold_in(key, 100 + i)
        return q.decode(k, q.encode(k, x, dist), ref)

    mean = jax.lax.map(one, jnp.arange(N)).mean(0)
    bias = float(jnp.linalg.norm(mean - x))
    per_coord = float(q.gamma_for(dist, d))
    # bias ≈ γ·sqrt(d/12N) for unbiased SR; allow 5 sigma
    assert bias <= 5 * per_coord * np.sqrt(d / (12 * N)), bias


def test_lattice_bits_accounting():
    q = LatticeQuantizer(bits=8)
    assert q.message_bits(16384) == 16384 * 8 + 32
    assert q.message_bits(16385) == 2 * 16384 * 8 + 32  # padded


@settings(deadline=None, max_examples=10)
@given(bits=st.integers(4, 10), seed=st.integers(0, 100))
def test_qsgd_unbiased_small(bits, seed):
    d, N = 256, 400
    q = QSGDQuantizer(bits=bits)
    key = jax.random.PRNGKey(seed)
    x = jax.random.normal(key, (d,))

    def one(i):
        k = jax.random.fold_in(key, i)
        return q.decode(k, q.encode(k, x))

    mean = jax.lax.map(one, jnp.arange(N)).mean(0)
    err = float(jnp.linalg.norm(mean - x)) / float(jnp.linalg.norm(x))
    assert err < 0.2, err


def test_make_quantizer_registry():
    for name in ("lattice", "qsgd", "none"):
        make_quantizer(name, 8)
    with pytest.raises(ValueError):
        make_quantizer("bogus", 8)


def test_wrap_failure_mode():
    """When the decoder's reference is FAR beyond the wrap window the
    positional decode is wrong — the regime Lemma 3.4's potential bound
    exists to prevent."""
    d = 1024
    q = LatticeQuantizer(bits=4, safety=1.0)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (d,))
    msg = q.encode(key, x, jnp.float32(0.01))  # hint far too small
    ref = x + jax.random.normal(jax.random.fold_in(key, 1), (d,)) * 10.0
    xh = q.decode(key, msg, ref)
    assert float(jnp.linalg.norm(xh - x)) > 1.0
