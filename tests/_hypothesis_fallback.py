"""Tiny deterministic stand-in for ``hypothesis`` (property-test shim).

The seed container has no ``hypothesis`` wheel and nothing may be pip
installed, so the property tests fall back to this shim: ``@given`` draws a
fixed number of pseudo-random examples per strategy from a deterministic
numpy generator (seeded per test name) and runs the test body once per
example. Boundary values are always included for integer ranges, which is
where the real failures live (padding edges, block boundaries).

Only the strategy surface the test-suite uses is implemented: ``integers``,
``floats``, ``sampled_from``. When the real ``hypothesis`` is available the
tests import it instead — this module is behaviour-compatible for our usage,
not a general replacement.
"""
from __future__ import annotations

import zlib

import numpy as np

_DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw, boundary=()):
        self._draw = draw
        self.boundary = tuple(boundary)

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the hypothesis module name
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            boundary=(min_value, max_value))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            boundary=(min_value,))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                         boundary=seq[:1])


st = strategies


def settings(deadline=None, max_examples=_DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(**strategy_kwargs):
    def deco(fn):
        # NOT functools.wraps: pytest must see a zero-arg signature, or it
        # would treat the strategy parameters as fixtures.
        def wrapper():
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
            # crc32, not hash(): str hashing is salted per process and
            # would make "deterministic" examples unreproducible
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()))
            names = sorted(strategy_kwargs)
            # boundary example first (min of every strategy), then random
            cases = [{k: strategy_kwargs[k].boundary[0]
                      for k in names
                      if strategy_kwargs[k].boundary}]
            if len(cases[0]) != len(names):
                cases = []
            while len(cases) < max(n, 1):
                cases.append({k: strategy_kwargs[k].draw(rng)
                              for k in names})
            for case in cases:
                try:
                    fn(**case)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example {case!r}: {e}") from e
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco
