"""Unified federated-algorithm API: registry, protocol, simulate, metrics.

Pins the api_redesign four ways:

  * every registry name builds, satisfies the FedAlgorithm protocol, and a
    round through the registry object is BIT-IDENTICAL to the legacy class
    (the registry is thin plumbing, not a reimplementation),
  * the event-driven FedBuff round() path and its legacy run() entry point
    drive the same completion stream (same seeds -> same server),
  * the standardized metrics schema (sim_time / bits_up / bits_down /
    h_steps_mean / quant_err) holds for every algorithm, and the split bit
    counters match ``tree_bits`` per direction,
  * simulate()/compare() respect round, sim-time, and bits budgets.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import (AdaptiveBits, AdaptiveQuAFL, FedAvg, FedBuff, QuAFL,
                        QuaflScaffold, Sequential)
from repro.core.transport import tree_bits
from repro.compression.lattice import make_quantizer
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import (FedAlgorithm, METRIC_KEYS, compare, make_algorithm,
                       normalize_metrics, registered_algorithms, simulate)
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.utils.tree import tree_flatten_vector

ALL_NAMES = ("quafl", "fedavg", "fedbuff", "sequential", "quafl_scaffold",
             "adaptive_quafl", "fedbuff_device", "spmd",
             "compressed_fedavg")

# spmd wraps the mesh-sharded LM train step: it needs a ModelConfig and
# token data, so the MLP-task smoke loops skip it (tests/test_engine.py
# covers it end to end through simulate()).
_MLP_NAMES = tuple(n for n in ALL_NAMES if n != "spmd")

LEGACY = {"quafl": QuAFL, "fedavg": FedAvg, "sequential": Sequential,
          "quafl_scaffold": QuaflScaffold}


def _setup(fed, seed=0, iid=True, d=16, hidden=32, classes=4):
    part, test = make_federated_classification(seed, fed.n_clients, d=d,
                                               n_classes=classes, iid=iid)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), d, hidden,
                                     classes)
    bf = lambda dd, k: client_batch(k, dd, d)
    return part, test, params0, bf


_SMOKE_CACHE = {}


def _smoke_setup():
    """Shared tiny task for the perf_smoke tests (built once per session)."""
    if not _SMOKE_CACHE:
        fed = FedConfig(n_clients=2, s=1, local_steps=1, lr=0.2, bits=6,
                        quantizer="qsgd")
        _SMOKE_CACHE["v"] = (fed,) + _setup(fed, d=8, hidden=8, classes=2)
    return _SMOKE_CACHE["v"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@pytest.mark.perf_smoke
def test_registry_names_and_protocol():
    assert registered_algorithms() == ALL_NAMES
    fed, part, test, params0, bf = _smoke_setup()
    for name in _MLP_NAMES:
        alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf)
        assert isinstance(alg, FedAlgorithm), name
    with pytest.raises(ValueError):
        make_algorithm("sgd", fed, loss_fn=mlp_loss, template=params0,
                       batch_fn=bf)


@pytest.mark.perf_smoke
def test_every_registered_algorithm_steps_once():
    """Instantiate and step EVERY registry algorithm once (CI smoke).

    Deliberately minimal shapes (1 sampled client, 1 local step, qsgd — the
    lattice pipeline would pad to the 16k Hadamard block): the budget is six
    XLA compiles in <10s, and this test only checks the registry ->
    protocol -> metrics-schema plumbing. The jitted lattice paths are
    pinned by the non-smoke tests here and by test_pipeline.py."""
    fed, part, test, params0, bf = _smoke_setup()
    for name in _MLP_NAMES:
        kw = ({"buffer_size": 1}
              if name in ("fedbuff", "fedbuff_device") else {})
        alg = make_algorithm(name, fed, loss_fn=mlp_loss,
                             template=params0, batch_fn=bf, **kw)
        state, m = alg.round(alg.init(params0), part,
                             jax.random.PRNGKey(1))
        norm = normalize_metrics(m)
        for k in METRIC_KEYS:
            assert k in m, (name, k)
            assert np.isfinite(norm[k]), (name, k, norm[k])
        assert np.all(np.isfinite(np.asarray(
            tree_flatten_vector(alg.eval_params(state))))), name


# ---------------------------------------------------------------------------
# bit-for-bit equivalence: registry object == legacy class
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(LEGACY))
def test_registry_round_matches_legacy_bitwise(name):
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=8)
    part, test, params0, bf = _setup(fed)
    legacy = LEGACY[name](fed=fed, loss_fn=mlp_loss, template=params0,
                          batch_fn=bf)
    reg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    sl, sr = legacy.init(params0), reg.init(params0)
    key = jax.random.PRNGKey(3)
    for _ in range(3):
        key, sub = jax.random.split(key)
        sl, ml = legacy.round(sl, part, sub)
        sr, mr = reg.round(sr, part, sub)
    fl = tree_flatten_vector(legacy.eval_params(sl))
    fr = tree_flatten_vector(reg.eval_params(sr))
    np.testing.assert_array_equal(np.asarray(fl), np.asarray(fr))
    assert normalize_metrics(ml) == normalize_metrics(mr)


def test_fedbuff_round_path_matches_run_path():
    """The protocol round() (advance-to-flush) and the legacy run() entry
    point drive the same single-completion step: same key -> identical
    server after the same flushes."""
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.2)
    part, test, params0, bf = _setup(fed)
    key = jax.random.PRNGKey(11)
    mk = lambda: make_algorithm("fedbuff", fed, loss_fn=mlp_loss,
                                template=params0, batch_fn=bf,
                                buffer_size=3, server_lr=0.5)
    alg = mk()
    state = alg.init(params0)
    for _ in range(3):
        state, m = alg.round(state, part, key)
        assert m["buffer_flushes"] == 1.0
    t_end = float(state.sim_time)

    # evals fire BEFORE the event at their grid time, so stretch total_time
    # past the last flush by a couple of grid steps: the tail evals then
    # report the post-flush server (one stray completion cannot flush again
    # with an empty buffer of size 3, so the server stays put).
    dt = max(t_end / 64, 1e-2)
    hist = mk().run(params0, part, key, total_time=t_end + 2 * dt,
                    eval_every=dt,
                    eval_fn=lambda p: np.asarray(tree_flatten_vector(p)))
    np.testing.assert_array_equal(hist[-1][1], np.asarray(state.server))


def test_adaptive_registry_matches_legacy_wrapper():
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=10)
    part, test, params0, bf = _setup(fed)
    reg = make_algorithm("adaptive_quafl", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf)
    legacy = AdaptiveQuAFL(
        fed, lambda f: QuAFL(fed=f, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf), params0)
    state = reg.init(params0)
    key = jax.random.PRNGKey(5)
    for _ in range(6):
        key, sub = jax.random.split(key)
        state, _ = reg.round(state, part, sub)
        legacy.round(part, sub)
    assert list(state.trace) == legacy.bits_trace
    np.testing.assert_array_equal(
        np.asarray(tree_flatten_vector(reg.eval_params(state))),
        np.asarray(tree_flatten_vector(legacy.eval_params())))


# ---------------------------------------------------------------------------
# split bit accounting vs tree_bits, per direction
# ---------------------------------------------------------------------------

def test_quafl_bits_split_matches_tree_bits():
    fed = FedConfig(n_clients=6, s=3, local_steps=1, bits=8)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    st0 = alg.init(params0)
    st1, m = alg.round(st0, part, jax.random.PRNGKey(0))
    msg_tree = {"model": jnp.zeros((alg.d,))}   # one flat model message
    per_msg = tree_bits(alg.quant, msg_tree)
    # s uplink messages, ONE downlink broadcast
    assert float(st1.bits_up) == fed.s * per_msg == float(m["bits_up"])
    assert float(st1.bits_down) == per_msg == float(m["bits_down"])
    assert float(st1.bits_sent) == float(st1.bits_up) + float(st1.bits_down)


def test_fedavg_bits_split_matches_tree_bits():
    fed = FedConfig(n_clients=6, s=3, local_steps=1)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("fedavg", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    st1, m = alg.round(alg.init(params0), part, jax.random.PRNGKey(0))
    per_msg = tree_bits(make_quantizer("none", 32), {"m": jnp.zeros((alg.d,))})
    # uncompressed model each way for each of the s sampled clients
    assert float(st1.bits_up) == fed.s * per_msg == float(m["bits_up"])
    assert float(st1.bits_down) == fed.s * per_msg == float(m["bits_down"])


def test_scaffold_bits_split_is_doubled_quafl():
    fed = FedConfig(n_clients=6, s=3, local_steps=1, bits=8)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl_scaffold", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf)
    st1, m = alg.round(alg.init(params0), part, jax.random.PRNGKey(0))
    per_msg = tree_bits(alg.quant, {"m": jnp.zeros((alg.d,))})
    # model + control variate ride the exchange in both directions
    assert float(st1.base.bits_up) == 2 * fed.s * per_msg
    assert float(st1.base.bits_down) == 2 * per_msg
    assert float(m["bits_up"]) == 2 * fed.s * per_msg


def test_fedbuff_bits_split_per_flush():
    fed = FedConfig(n_clients=4, s=2, local_steps=1, bits=8)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("fedbuff", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, buffer_size=3, quantize=True,
                         quantizer="lattice")
    st1, m = alg.round(alg.init(params0), part, jax.random.PRNGKey(2))
    per_up = tree_bits(alg.quant, {"m": jnp.zeros((alg.d,))})
    # one quantized delta up + one fp32 restart model down per completion
    assert float(m["bits_up"]) == 3 * per_up
    assert float(m["bits_down"]) == 3 * alg.d * 32
    assert float(st1.bits_sent) == float(m["bits_up"]) + float(m["bits_down"])


# ---------------------------------------------------------------------------
# simulate / compare budgets
# ---------------------------------------------------------------------------

def test_simulate_round_and_time_budgets():
    fed = FedConfig(n_clients=6, s=3, local_steps=1, lr=0.2)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=5,
                  eval_every=2)
    assert tr.rounds == 5 and tr.final["round"] == 5
    # sim-time budget: quafl rounds last swt+sit=11s
    tr2 = simulate(alg, params0, part, jax.random.PRNGKey(1),
                   until_sim_time=50.0)
    assert tr2.rounds == 5 and tr2.final["sim_time"] >= 50.0
    with pytest.raises(ValueError):
        simulate(alg, params0, part, jax.random.PRNGKey(1))


def test_compare_equal_bits_budget():
    """Equal-bits comparison: every algorithm stops once its cumulative
    up+down bits cross the budget — QuAFL fits many more rounds in it."""
    fed = FedConfig(n_clients=6, s=3, local_steps=1, lr=0.2, bits=8)
    part, test, params0, bf = _setup(fed)
    algs = {n: make_algorithm(n, fed, loss_fn=mlp_loss, template=params0,
                              batch_fn=bf) for n in ("quafl", "fedavg")}
    budget = 40 * 4 * make_quantizer("lattice", 8).message_bits(
        algs["quafl"].d)
    traces = compare(algs, params0, part, jax.random.PRNGKey(2),
                     until_bits=budget, eval_every=0)
    for name, tr in traces.items():
        f = tr.final
        assert f["bits_up_total"] + f["bits_down_total"] >= budget, name
        # per-round schema keys keep their per-round meaning in rows
        assert f["bits_up"] <= f["bits_up_total"], name
    assert traces["quafl"].rounds > 3 * traces["fedavg"].rounds


def test_trace_format_is_uniform():
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2)
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    for name in ("quafl", "sequential"):
        alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf)
        tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=3,
                      eval_every=1,
                      eval_fn=lambda p: {"loss": float(mlp_loss(p, test)[0])})
        assert len(tr.rows) == 3
        for row in tr.rows:
            for k in METRIC_KEYS + ("round", "loss"):
                assert k in row, (name, k)
        # cumulative counters are monotone
        assert tr.column("sim_time") == sorted(tr.column("sim_time"))
        assert tr.column("bits_up_total") == sorted(
            tr.column("bits_up_total"))


def test_unreachable_budget_backstop_still_records_final_row():
    """sequential never sends a bit, so an until_bits budget is
    unreachable: the max_rounds backstop must end the run AND the final
    row (with its eval) must still exist."""
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2)
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("sequential", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), until_bits=1e6,
                  eval_every=0, max_rounds=7,
                  eval_fn=lambda p: {"loss": float(mlp_loss(p, test)[0])})
    assert tr.rounds == 7
    assert tr.final["round"] == 7 and "loss" in tr.final


def test_record_every_decouples_metrics_from_eval():
    """record_every traces dense metrics rows; eval_fn only runs on the
    eval cadence (plus the final round)."""
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2)
    part, test, params0, bf = _setup(fed, d=8, hidden=8, classes=2)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    n_evals = []
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=4,
                  eval_every=0, record_every=1,
                  eval_fn=lambda p: n_evals.append(1) or {"acc": 0.0})
    assert len(tr.rows) == 4 and len(n_evals) == 1   # eval only at done
    assert all("h_zero_frac" in r for r in tr.rows)
    assert [r["round"] for r in tr.rows] == [1, 2, 3, 4]
    assert "acc" in tr.rows[-1] and "acc" not in tr.rows[0]


# ---------------------------------------------------------------------------
# extensions through the registry + harness
# ---------------------------------------------------------------------------

def test_scaffold_through_registry_converges_noniid():
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3, bits=10)
    part, test, params0, bf = _setup(fed, iid=False)
    alg = make_algorithm("quafl_scaffold", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=40,
                  eval_every=20,
                  eval_fn=lambda p: {"loss": float(mlp_loss(p, test)[0])})
    assert tr.rows[-1]["loss"] < tr.rows[0]["loss"]
    assert np.isfinite(tr.rows[-1]["c_norm"])


def test_adaptive_walk_stays_in_bounds():
    """AdaptiveBits never leaves [b_min, b_max] — pure controller and the
    registry algorithm driven through simulate()."""
    b_min, b_max, bits = 4, 12, 8
    rng = np.random.default_rng(0)
    for rel in rng.uniform(0, 0.2, size=200):
        bits = AdaptiveBits.walk(bits, float(rel), 0.01, 0.05, b_min, b_max)
        assert b_min <= bits <= b_max

    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=12)
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("adaptive_quafl", fed, loss_fn=mlp_loss,
                         template=params0, batch_fn=bf, b_min=4, b_max=12)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(3), rounds=12,
                  eval_every=0)
    trace = tr.final_state.trace
    assert len(trace) == 12
    assert all(4 <= b <= 12 for b in trace)
    # lattice at b=12 has tiny error -> the walk must move DOWN
    assert trace[-1] < 12
