"""Beyond-paper extensions: QuAFL-SCAFFOLD + adaptive bit-width."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import QuAFL
from repro.core.extensions import AdaptiveBits, AdaptiveQuAFL, QuaflScaffold
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def _setup(fed, seed=0, iid=False):
    part, test = make_federated_classification(seed, fed.n_clients, d=16,
                                               n_classes=4, iid=iid)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), 16, 32, 4)
    bf = lambda d, k: client_batch(k, d, 16)
    return part, test, params0, bf


def test_scaffold_converges_noniid():
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3, bits=10)
    part, test, params0, bf = _setup(fed)
    alg = QuaflScaffold(fed=fed, loss_fn=mlp_loss, template=params0,
                        batch_fn=bf)
    st = alg.init(params0)
    key = jax.random.PRNGKey(1)
    loss0 = float(mlp_loss(alg.eval_params(st), test)[0])
    for _ in range(60):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
    loss1 = float(mlp_loss(alg.eval_params(st), test)[0])
    assert loss1 < 0.8 * loss0
    assert np.isfinite(float(m["c_norm"])) and float(m["c_norm"]) > 0


def test_scaffold_controls_reduce_drift():
    """With control variates the client spread (potential Φ) should be no
    larger than vanilla QuAFL under non-iid data."""
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3, bits=10)
    part, test, params0, bf = _setup(fed)

    def phi(server, clients, n):
        mu = (server + jnp.sum(clients, 0)) / (n + 1)
        return float(jnp.sum((clients - mu) ** 2)
                     + jnp.sum((server - mu) ** 2))

    base = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    sb = base.init(params0)
    sc_alg = QuaflScaffold(fed=fed, loss_fn=mlp_loss, template=params0,
                           batch_fn=bf)
    sc = sc_alg.init(params0)
    key = jax.random.PRNGKey(2)
    for _ in range(40):
        key, k1, k2 = jax.random.split(key, 3)
        sb, _ = base.round(sb, part, k1)
        sc, _ = sc_alg.round(sc, part, k2)
    p_base = phi(sb.server, sb.clients, fed.n_clients)
    p_scaf = phi(sc.base.server, sc.base.clients, fed.n_clients)
    assert p_scaf < 3.0 * p_base  # not exploding; usually smaller


def test_adaptive_bits_controller():
    c = AdaptiveBits(bits=8, lo=0.01, hi=0.05, b_min=4, b_max=12)
    assert c.update(0.10) == 9       # too much error -> more bits
    assert c.update(0.001) == 8      # too little -> fewer
    for _ in range(20):
        c.update(0.001)
    assert c.bits == c.b_min         # clamped


def test_adaptive_quafl_runs_and_adapts():
    fed = FedConfig(n_clients=8, s=4, local_steps=3, lr=0.3, bits=12)
    part, test, params0, bf = _setup(fed)
    wrap = AdaptiveQuAFL(
        fed, lambda f: QuAFL(fed=f, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf), params0)
    key = jax.random.PRNGKey(3)
    for _ in range(12):
        key, sub = jax.random.split(key)
        wrap.round(part, sub)
    assert len(wrap.bits_trace) == 12
    # lattice at b=12 has tiny error -> controller should walk bits DOWN
    assert wrap.bits_trace[-1] < 12
    loss, _ = mlp_loss(wrap.eval_params(), test)
    assert np.isfinite(float(loss))
