"""Population engine: sharded client-state store + Participation specs.

Pins the new_subsystem four ways:

  * the gather/scatter population path is bit-for-bit the pre-refactor
    dense path at small N — the spec-resolved ``uniform`` run reproduces
    the PR 3 golden anchor (``tests/golden_pr3.npz``) for quafl, fedavg,
    quafl_scaffold, and fedbuff_device, server vectors and bit counters,
  * participation schedules are pure functions of ``(key, t, n, s)``: the
    cyclic spec is deterministic across ``lax.scan`` chunk boundaries
    (eager == scanned bitwise, chunks straddling phase flips included),
  * per-client RNG derives lazily from ``(base_key, client_id)``: draws are
    stable under sample reordering AND under resharding the store over an
    8-device client mesh (subprocess),
  * N is a spec, not a hot-path cost: the ``perf_smoke`` gate runs the
    scanned engine at N=10^3 and N=10^5 (fixed s=8) and asserts the
    us_per_round stays flat (Floyd's sampler — no O(N log N) permutation).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import (CyclicParticipation, GammaStragglerParticipation,
                       UniformParticipation, build_population, client_keys,
                       floyd_sample, gather_rows, make_algorithm,
                       register_participation, registered_participations,
                       resolve_participation, sample_clients, scatter_rows,
                       simulate, uniform_sample, with_rows)
from repro.fed.population import DENSE_SAMPLE_MAX, lazy_h_steps_per_client
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.utils.tree import tree_flatten_vector

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st


GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden_pr3.npz")


def _setup(fed, seed=0, d=16, hidden=32, classes=4):
    part, test = make_federated_classification(seed, fed.n_clients, d=d,
                                               n_classes=classes)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), d, hidden,
                                     classes)
    bf = lambda dd, k: client_batch(k, dd, d)
    return part, test, params0, bf


# ---------------------------------------------------------------------------
# the store: build / gather / scatter / rows
# ---------------------------------------------------------------------------

def test_build_population_speed_groups():
    fed = FedConfig(n_clients=10, s=2, slow_frac=0.3)
    pop = build_population(fed)
    assert pop.n == 10
    lam, group = np.asarray(pop.row("lam")), np.asarray(pop.row("group"))
    # the clock's split: first slow_frac*n clients are slow (group label 1)
    assert group.sum() == 3 and group[:3].all()
    np.testing.assert_array_equal(lam[:3], fed.lam_slow)
    np.testing.assert_array_equal(lam[3:], fed.lam_fast)


def test_gather_scatter_roundtrip():
    fed = FedConfig(n_clients=8, s=3)
    pop = build_population(fed, model=jnp.arange(8 * 4, dtype=jnp.float32)
                           .reshape(8, 4))
    idx = jnp.asarray([6, 1, 4])
    got = gather_rows(pop, idx)
    np.testing.assert_array_equal(np.asarray(got["lam"]),
                                  np.asarray(pop.row("lam"))[[6, 1, 4]])
    pop2 = scatter_rows(pop, idx, {"model": got["model"] + 100.0})
    m2 = np.asarray(pop2.row("model"))
    m0 = np.asarray(pop.row("model"))
    np.testing.assert_array_equal(m2[[6, 1, 4]], m0[[6, 1, 4]] + 100.0)
    untouched = [i for i in range(8) if i not in (6, 1, 4)]
    np.testing.assert_array_equal(m2[untouched], m0[untouched])
    # rows not named in the scatter are carried through BY REFERENCE
    assert pop2.row("lam") is pop.row("lam")
    # with_rows adds without copying existing rows
    pop3 = with_rows(pop, extra=jnp.zeros((8,)))
    assert pop3.row("model") is pop.row("model") and pop3.n == 8


# ---------------------------------------------------------------------------
# samplers: legacy pin below the threshold, Floyd above it
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_uniform_sample_pins_legacy_draw_at_small_n(seed):
    """Below DENSE_SAMPLE_MAX the uniform sampler IS clock.sample_clients
    bit-for-bit — the golden anchors (and every existing seeded run) live
    on this branch."""
    key = jax.random.PRNGKey(seed)
    np.testing.assert_array_equal(np.asarray(uniform_sample(key, 64, 5)),
                                  np.asarray(sample_clients(key, 64, 5)))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_floyd_sample_is_valid_subset(seed):
    n, s = 50_000, 8
    ids = np.asarray(floyd_sample(jax.random.PRNGKey(seed), n, s))
    assert len(set(ids.tolist())) == s          # without replacement
    assert (ids >= 0).all() and (ids < n).all()


def test_uniform_sample_switches_to_floyd_above_threshold():
    key, n, s = jax.random.PRNGKey(3), DENSE_SAMPLE_MAX + 1, 6
    np.testing.assert_array_equal(np.asarray(uniform_sample(key, n, s)),
                                  np.asarray(floyd_sample(key, n, s)))


def test_floyd_sample_is_roughly_uniform():
    """Every client must be reachable with about equal frequency — Floyd's
    duplicate->j redirect must not visibly bias the tail indices."""
    n, s, rounds = 40, 5, 2000
    counts = np.zeros(n)
    for r in range(rounds):
        ids = np.asarray(floyd_sample(jax.random.PRNGKey(r), n, s))
        counts[ids] += 1
    expect = rounds * s / n                      # 250 per client
    assert counts.min() > 0.7 * expect and counts.max() < 1.3 * expect


# ---------------------------------------------------------------------------
# participation specs: semantics, registry, grammar
# ---------------------------------------------------------------------------

def test_resolve_participation_precedence():
    inst = CyclicParticipation(period=4, phase_groups=2)
    assert resolve_participation(inst) is inst
    assert isinstance(resolve_participation("uniform"),
                      UniformParticipation)
    fed = FedConfig(n_clients=8, s=2,
                    participation="gamma_straggler:strength=2")
    p = resolve_participation(None, fed)
    assert isinstance(p, GammaStragglerParticipation) and p.strength == 2
    # explicit spec overrides the config default
    assert isinstance(resolve_participation("uniform", fed),
                      UniformParticipation)
    assert isinstance(resolve_participation(None, None),
                      UniformParticipation)


def test_participation_spec_grammar_errors():
    with pytest.raises(ValueError, match="unknown participation"):
        resolve_participation("diurnal")
    with pytest.raises(ValueError, match="malformed"):
        resolve_participation("cyclic:period8")
    with pytest.raises(TypeError):
        resolve_participation(42)
    with pytest.raises(ValueError, match="period"):
        CyclicParticipation(period=3, phase_groups=2)
    with pytest.raises(ValueError, match="period >= phase_groups"):
        CyclicParticipation(period=2, phase_groups=4)


def test_participation_registry_extensible_and_loud_on_duplicates():
    names = registered_participations()
    assert {"uniform", "gamma_straggler", "cyclic"} <= set(names)
    with pytest.raises(ValueError, match="already registered"):
        register_participation("uniform", UniformParticipation)
    register_participation("test_everyone_0",
                           lambda **kw: UniformParticipation())
    try:
        assert isinstance(resolve_participation("test_everyone_0"),
                          UniformParticipation)
    finally:
        from repro.fed.population import _PARTICIPATIONS
        _PARTICIPATIONS.pop("test_everyone_0", None)


def test_cyclic_sample_stays_in_active_phase_group():
    p = CyclicParticipation(period=8, phase_groups=4)   # 2 rounds per phase
    n, s, m = 20, 3, 5
    for t in range(16):
        g = int(p.group_at(t))
        assert g == (t // 2) % 4
        ids = np.asarray(p.sample(jax.random.PRNGKey(t), t, n, s))
        assert len(set(ids.tolist())) == s
        assert (ids >= g * m).all() and (ids < (g + 1) * m).all()


def test_cyclic_validates_population_shape_at_trace_time():
    p = CyclicParticipation(period=4, phase_groups=2)
    with pytest.raises(ValueError, match="divisible"):
        p.sample(jax.random.PRNGKey(0), 0, 9, 2)
    with pytest.raises(ValueError, match="exceeds"):
        p.sample(jax.random.PRNGKey(0), 0, 8, 5)


def test_gamma_straggler_prefers_fast_clients():
    """Availability ∝ λ^strength: the fast 70% must answer polls far more
    often per client than the slow 30% (λ_fast/λ_slow = 4 here)."""
    fed = FedConfig(n_clients=50, s=5, slow_frac=0.4)
    pop = build_population(fed)
    lam = pop.row("lam")
    p = GammaStragglerParticipation(strength=2.0)
    counts = np.zeros(50)
    for r in range(400):
        ids = np.asarray(p.sample(jax.random.PRNGKey(r), r, 50, 5, lam))
        assert len(set(ids.tolist())) == 5
        counts[ids] += 1
    slow = counts[:20].mean()
    fast = counts[20:].mean()
    assert fast > 3.0 * slow, (slow, fast)
    with pytest.raises(ValueError, match="lam"):
        p.sample(jax.random.PRNGKey(0), 0, 50, 5, None)


# ---------------------------------------------------------------------------
# lazy per-client RNG: identity-keyed, order- and sharding-invariant
# ---------------------------------------------------------------------------

def test_client_keys_are_identity_keyed():
    base = jax.random.PRNGKey(9)
    a = np.asarray(client_keys(base, jnp.asarray([5, 1, 9])))
    b = np.asarray(client_keys(base, jnp.asarray([9, 5, 1])))
    np.testing.assert_array_equal(a[0], b[1])
    np.testing.assert_array_equal(a[1], b[2])
    np.testing.assert_array_equal(a[2], b[0])
    # and equal to the scalar derivation
    np.testing.assert_array_equal(
        a[0], np.asarray(jax.random.fold_in(base, 5)))


def test_lazy_h_steps_per_client_stable_under_reordering():
    base = jax.random.PRNGKey(4)
    lam = jnp.asarray([0.5, 0.125, 0.5, 0.125], jnp.float32)
    elapsed = jnp.asarray([10.0, 20.0, 30.0, 40.0], jnp.float32)
    ids = jnp.asarray([3, 0, 2, 1])
    h1 = np.asarray(lazy_h_steps_per_client(base, ids, lam[ids],
                                            elapsed[ids], 10))
    perm = jnp.asarray([1, 3, 0, 2])    # same clients, different order
    h2 = np.asarray(lazy_h_steps_per_client(base, ids[perm], lam[ids][perm],
                                            elapsed[ids][perm], 10))
    np.testing.assert_array_equal(h1[np.asarray(perm)], h2)
    assert (h1 <= 10).all() and (h1 >= 0).all()


# ---------------------------------------------------------------------------
# population path == dense path: the PR 3 golden anchor through the specs
# ---------------------------------------------------------------------------

GOLDEN_ALGS = {
    "quafl": {},
    "quafl_scaffold": {},
    "fedavg": {},
    "fedbuff_device": dict(buffer_size=2, quantize=True,
                           quantizer="lattice"),
}


@pytest.mark.parametrize("name", sorted(GOLDEN_ALGS))
def test_population_path_matches_pr3_golden(name):
    """The store-backed gather/scatter round (with the participation spec
    resolved EXPLICITLY, not defaulted) reproduces the pre-population
    golden slice bit-for-bit: server vector and per-round bit counters."""
    golden = np.load(GOLDEN_PATH)
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=8)
    part, _, params0, bf = _setup(fed)
    kw = dict(GOLDEN_ALGS[name])
    if name != "fedbuff_device":    # event-driven: no per-round draw
        kw["participation"] = UniformParticipation()
    alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, **kw)
    state = alg.init(params0)
    key = jax.random.PRNGKey(7)
    ups, downs = [], []
    for _ in range(3):
        key, sub = jax.random.split(key)
        state, m = alg.round(state, part, sub)
        ups.append(float(m["bits_up"]))
        downs.append(float(m["bits_down"]))
    np.testing.assert_array_equal(
        np.asarray(tree_flatten_vector(alg.eval_params(state))),
        golden[f"{name}/server"])
    np.testing.assert_array_equal(np.asarray(ups), golden[f"{name}/bits_up"])
    np.testing.assert_array_equal(np.asarray(downs),
                                  golden[f"{name}/bits_down"])


def test_population_larger_than_cohort_trains():
    """n_clients > s through every sampling algorithm: the store holds n
    rows, the round exchanges s messages (bits accounting unchanged)."""
    fed = FedConfig(n_clients=24, s=4, local_steps=2, lr=0.3, bits=8,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    for name in ("quafl", "fedavg", "quafl_scaffold"):
        alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf,
                             participation="gamma_straggler:strength=1")
        tr = simulate(alg, params0, part, jax.random.PRNGKey(2), rounds=4,
                      eval_every=0)
        v = np.asarray(tree_flatten_vector(alg.eval_params(tr.final_state)))
        assert np.isfinite(v).all(), name
        assert tr.final["bits_up"] > 0


# ---------------------------------------------------------------------------
# cyclic determinism across scan chunk boundaries
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ("quafl", "fedavg"))
def test_cyclic_schedule_deterministic_across_chunk_boundaries(name):
    """8 rounds of cyclic:period=4,phase_groups=2 under scan_chunk=3
    (chunks 3,3,2 — every chunk straddles a phase flip): the scanned run
    must be bit-for-bit the eager run, because the schedule is a pure
    function of the round counter t carried in the state."""
    fed = FedConfig(n_clients=8, s=2, local_steps=2, lr=0.3,
                    quantizer="qsgd",
                    participation="cyclic:period=4,phase_groups=2")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    run = lambda chunk: simulate(alg, params0, part, jax.random.PRNGKey(5),
                                 rounds=8, eval_every=0, record_every=1,
                                 scan_chunk=chunk)
    tre, trs = run(0), run(3)
    assert tre.engine == "eager" and trs.engine == "scanned"
    fe = np.asarray(tree_flatten_vector(alg.eval_params(tre.final_state)))
    fs = np.asarray(tree_flatten_vector(alg.eval_params(trs.final_state)))
    np.testing.assert_array_equal(fe, fs)
    for re, rs in zip(tre.rows, trs.rows):
        for k in ("sim_time", "bits_up_total", "h_steps_mean"):
            assert re[k] == rs[k], (re["round"], k)


def test_cyclic_last_time_rows_respect_schedule():
    """Only the active phase group's clients interact: after the first
    phase (2 rounds of group 0) no group-1 client may have a last_time
    update yet, and over a full period every group gets touched."""
    fed = FedConfig(n_clients=8, s=4, local_steps=1, lr=0.1,
                    quantizer="qsgd",
                    participation="cyclic:period=2,phase_groups=2")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    state = alg.init(params0)
    state, _ = alg.round(state, part, jax.random.PRNGKey(0))
    lt = np.asarray(state.last_time)
    assert (lt[:4] > 0).all() and (lt[4:] == 0).all()   # s=4 = group size
    state, _ = alg.round(state, part, jax.random.PRNGKey(1))
    lt = np.asarray(state.last_time)
    assert (lt > 0).all()


# ---------------------------------------------------------------------------
# resharding: an 8-device client mesh must not change ANY draw or iterate
# ---------------------------------------------------------------------------

SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import (build_population, client_keys, client_mesh,
                       make_algorithm, shard_population, simulate)
from repro.fed.population import lazy_h_steps_per_client
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.utils.tree import tree_flatten_vector

assert jax.device_count() == 8
mesh = client_mesh()
fed = FedConfig(n_clients=16, s=4, local_steps=2, lr=0.3, quantizer="qsgd",
                participation="gamma_straggler:strength=1")

# 1) sharding moves placement, never values
pop = build_population(fed, model=jnp.arange(16 * 4, dtype=jnp.float32)
                       .reshape(16, 4))
sh = shard_population(pop, mesh)
for name in pop.rows:
    np.testing.assert_array_equal(np.asarray(sh.rows[name]),
                                  np.asarray(pop.rows[name]))
assert len(set(d.device for d in sh.rows["model"].addressable_shards)) == 8

# 2) per-client draws are identity-keyed: identical from sharded and
#    unsharded lam rows
base = jax.random.PRNGKey(3)
ids = jnp.asarray([13, 2, 7, 11])
h_dense = lazy_h_steps_per_client(base, ids, pop.rows["lam"][ids],
                                  jnp.full((4,), 12.0), 10)
h_shard = lazy_h_steps_per_client(base, ids, sh.rows["lam"][ids],
                                  jnp.full((4,), 12.0), 10)
np.testing.assert_array_equal(np.asarray(h_dense), np.asarray(h_shard))
np.testing.assert_array_equal(np.asarray(client_keys(base, ids)),
                              np.asarray(jnp.stack(
                                  [jax.random.fold_in(base, int(i))
                                   for i in ids])))

# 3) a full run with the store sharded over the client mesh is bit-for-bit
#    the unsharded run (gamma participation exercises per-client keys)
part, _ = make_federated_classification(0, 16, d=16, n_classes=4)
params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 16, 32, 4)
bf = lambda dd, k: client_batch(k, dd, 16)
servers = {}
for label, cm in (("dense", None), ("sharded", mesh)):
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, client_mesh=cm)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(5), rounds=4,
                  eval_every=0)
    servers[label] = np.asarray(
        tree_flatten_vector(alg.eval_params(tr.final_state)))
np.testing.assert_array_equal(servers["dense"], servers["sharded"])
print("POP_SUBPROC_OK")
"""


def test_rng_and_rounds_stable_under_resharding_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "POP_SUBPROC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# scan_chunk="auto": the tuned run equals the explicit run bitwise
# ---------------------------------------------------------------------------

def test_auto_chunk_matches_explicit_bitwise():
    fed = FedConfig(n_clients=8, s=3, local_steps=2, lr=0.3,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("quafl", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    tra = simulate(alg, params0, part, jax.random.PRNGKey(2), rounds=12,
                   eval_every=0, record_every=1, scan_chunk="auto")
    assert tra.engine == "scanned" and tra.scan_chunk >= 2
    assert alg._round_engine.tuned_chunk == tra.scan_chunk   # cached
    trk = simulate(alg, params0, part, jax.random.PRNGKey(2), rounds=12,
                   eval_every=0, record_every=1,
                   scan_chunk=tra.scan_chunk)
    fa = np.asarray(tree_flatten_vector(alg.eval_params(tra.final_state)))
    fk = np.asarray(tree_flatten_vector(alg.eval_params(trk.final_state)))
    np.testing.assert_array_equal(fa, fk)
    assert [r["sim_time"] for r in tra.rows] == \
        [r["sim_time"] for r in trk.rows]


def test_auto_chunk_capped_by_eval_cadence():
    """Autotune must never pick a chunk longer than the eval cadence —
    evals only fire on chunk boundaries."""
    fed = FedConfig(n_clients=6, s=2, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("fedavg", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf)
    eval_fn = lambda p: {"loss": float(mlp_loss(p, test)[0])}
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=9,
                  eval_every=3, eval_fn=eval_fn, scan_chunk="auto")
    assert 2 <= tr.scan_chunk <= 3
    assert [r["round"] for r in tr.rows] == [3, 6, 9]
    assert all("loss" in r for r in tr.rows)


def test_auto_chunk_falls_back_eager_for_host_algorithms():
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2,
                    quantizer="qsgd")
    part, test, params0, bf = _setup(fed)
    alg = make_algorithm("fedbuff", fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=bf, buffer_size=2)
    tr = simulate(alg, params0, part, jax.random.PRNGKey(1), rounds=3,
                  eval_every=0, scan_chunk="auto")
    assert tr.engine == "eager" and tr.scan_chunk == 0


# ---------------------------------------------------------------------------
# perf gate: N is memory, not per-round time
# ---------------------------------------------------------------------------

def _flat_alg(n_clients: int, d: int = 256):
    fed = FedConfig(n_clients=n_clients, s=8, local_steps=2, lr=0.01,
                    quantizer="none")
    key = jax.random.PRNGKey(0)
    params0 = {"w": 0.01 * jax.random.normal(key, (d,), jnp.float32)}
    data = {"c": jnp.ones((1, 4), jnp.float32)}   # shared tiny batch pool

    def loss_fn(params, batch):
        w = params["w"]
        return 0.5 * jnp.mean(batch["c"]) * jnp.sum(w * w), {}

    def bf(client_data, k):
        return {"c": client_data["c"]}

    alg = make_algorithm("quafl", fed, loss_fn=loss_fn, template=params0,
                         batch_fn=bf)
    return alg, params0, data


@pytest.mark.perf_smoke
def test_perf_smoke_round_cost_independent_of_population_size():
    """The population engine's contract: us_per_round at N=10^5 within
    1.5x of N=10^3 (fixed s=8, scanned engine) — the uniform sampler must
    be on Floyd's O(s^2) branch, the state updates on the O(s·d)
    gather/scatter, with no hidden O(N) per-round work besides the O(N)
    carry XLA keeps resident."""
    us = {}
    for n in (1_000, 100_000):
        alg, params0, data = _flat_alg(n)
        for _ in range(2):   # compile+warmup, then the timed run
            tr = simulate(alg, params0, data, jax.random.PRNGKey(3),
                          rounds=40, eval_every=0, scan_chunk=10)
        assert tr.engine == "scanned"
        us[n] = tr.us_per_round
    # generous floor so sub-ms timing jitter can't fail a healthy run
    base = max(us[1_000], 200.0)
    assert us[100_000] < 1.5 * base, us
