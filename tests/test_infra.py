"""Optimizers, checkpointing, serving engine, HLO cost walker, roofline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import SHAPES, get_config, get_reduced
from repro.launch.hlocost import analyze_hlo
from repro.launch.roofline import active_params, model_flops, roofline
from repro.models.model import init_lm
from repro.optim import adam, sgd
from repro.serving import Request, ServeEngine


@pytest.mark.parametrize("opt", [sgd(0.1), sgd(0.1, momentum=0.9),
                                 adam(0.05)])
def test_optimizers_minimize_quadratic(opt):
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        upd, state = opt.update(grads, state, params)
        params = {"w": params["w"] - upd["w"]}
    assert float(jnp.linalg.norm(params["w"])) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_reduced("olmo-1b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 7, params, extra={"arch": cfg.name})
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path), 7, params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]))


def test_serving_engine_batched():
    cfg = get_reduced("llama3.2-1b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=3, max_seq=64, temperature=0.0)
    for i in range(5):
        eng.submit(Request(prompt=[1 + i, 2, 3], max_new_tokens=6))
    done = eng.run()
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serving_greedy_deterministic():
    cfg = get_reduced("gemma2-2b")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServeEngine(cfg, params, max_batch=2, max_seq=32)
        eng.submit(Request(prompt=[5, 6, 7], max_new_tokens=5))
        outs.append(eng.run()[0].out_tokens)
    assert outs[0] == outs[1]


def test_hlocost_scan_equals_unrolled():
    def body(x, w):
        return jnp.tanh(x @ w), None
    w = jnp.zeros((4, 64, 64))
    x = jnp.ones((8, 64))
    t1 = jax.jit(lambda x, w: jax.lax.scan(body, x, w)[0]).lower(
        x, w).compile().as_text()
    def unrolled(x, w):
        for i in range(4):
            x, _ = body(x, w[i])
        return x
    t2 = jax.jit(unrolled).lower(x, w).compile().as_text()
    r1, r2 = analyze_hlo(t1), analyze_hlo(t2)
    assert r1["flops"] == r2["flops"] == 2 * 8 * 64 * 64 * 4


def test_roofline_terms_and_bottleneck():
    t = roofline(197e12, 0.0, {})
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["bottleneck"] == "compute"
    t = roofline(0.0, 0.0, {"all-reduce": {"bytes": 50e9, "count": 1}})
    assert abs(t["collective_s"] - 2.0) < 1e-9  # ring factor 2
    assert t["bottleneck"] == "collective"


def test_active_params_moe_scaling():
    dense = get_config("olmo-1b")
    assert abs(active_params(dense) / 1.33e9 - 1) < 0.15
    moe = get_config("llama4-scout-17b-a16e")
    total_like = active_params(moe)
    # ~17B activated for scout (16 routed -> 1 active + 1 shared)
    assert 10e9 < total_like < 25e9, total_like


def test_model_flops_decode_vs_train():
    cfg = get_config("olmo-1b")
    tr = model_flops(cfg, SHAPES["train_4k"], local_steps=2, n_slots=16)
    de = model_flops(cfg, SHAPES["decode_32k"], local_steps=2, n_slots=16)
    assert tr > de * 1e4
