"""QuAFL algorithm invariants + convergence (paper Alg. 1, §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import FedConfig
from repro.core import FedAvg, QuAFL, Sequential, expected_steps, client_speeds
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def _setup(fed, seed=0, iid=True, **kw):
    part, test = make_federated_classification(seed, fed.n_clients, d=16,
                                               n_classes=4, iid=iid)
    key = jax.random.PRNGKey(seed)
    params0, _ = init_mlp_classifier(key, 16, 32, 4)
    alg = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0,
                batch_fn=lambda d, k: client_batch(k, d, 16), **kw)
    return alg, alg.init(params0), part, test, key


def test_mean_preservation_no_steps_no_quant():
    """With lr=0 and no quantization, a round is pure (s+1)-averaging and
    the model mean μ_t is EXACTLY preserved (paper §2.2 'Model Averaging')."""
    fed = FedConfig(n_clients=8, s=3, local_steps=2, lr=0.0, quantizer="none")
    alg, st, part, _, key = _setup(fed)
    # diverge the clients artificially
    st = st.with_clients(st.clients + jax.random.normal(
        key, st.clients.shape))
    mu0 = (st.server + jnp.sum(st.clients, 0)) / (fed.n_clients + 1)
    st2, _ = alg.round(st, part, key)
    mu1 = (st2.server + jnp.sum(st2.clients, 0)) / (fed.n_clients + 1)
    np.testing.assert_allclose(np.asarray(mu1), np.asarray(mu0), atol=1e-5)


def test_clients_contract_towards_server():
    """The (s+1)-averaging strictly decreases the potential Φ when lr=0."""
    fed = FedConfig(n_clients=6, s=6, local_steps=1, lr=0.0, quantizer="none")
    alg, st, part, _, key = _setup(fed)
    st = st.with_clients(st.clients + jax.random.normal(
        key, st.clients.shape))

    def phi(s):
        mu = (s.server + jnp.sum(s.clients, 0)) / (fed.n_clients + 1)
        return float(jnp.sum((s.clients - mu) ** 2) +
                     jnp.sum((s.server - mu) ** 2))

    p0 = phi(st)
    st2, _ = alg.round(st, part, key)
    assert phi(st2) < p0


@pytest.mark.parametrize("quantizer", ["lattice", "qsgd", "none"])
def test_quafl_converges(quantizer):
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3,
                    quantizer=quantizer, bits=10, swt=10.0)
    alg, st, part, test, key = _setup(fed)
    loss0, _ = mlp_loss(alg.eval_params(st), test)
    for _ in range(60):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
    loss1, metr = mlp_loss(alg.eval_params(st), test)
    assert float(loss1) < 0.7 * float(loss0), (float(loss0), float(loss1))
    assert float(metr["acc"]) > 0.5


def test_quafl_noniid_converges():
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3, bits=10)
    alg, st, part, test, key = _setup(fed, iid=False)
    for _ in range(80):
        key, sub = jax.random.split(key)
        st, _ = alg.round(st, part, sub)
    loss, metr = mlp_loss(alg.eval_params(st), test)
    assert float(metr["acc"]) > 0.4


def test_mean_model_tracks_server():
    """Corollary 3.3: server stays close to the mean of local models."""
    fed = FedConfig(n_clients=8, s=4, local_steps=2, lr=0.1)
    alg, st, part, test, key = _setup(fed)
    for _ in range(30):
        key, sub = jax.random.split(key)
        st, _ = alg.round(st, part, sub)
    mu = (st.server + jnp.sum(st.clients, 0)) / (fed.n_clients + 1)
    rel = float(jnp.linalg.norm(st.server - mu) / jnp.linalg.norm(mu))
    assert rel < 0.2, rel


def test_weighted_dampening():
    fed = FedConfig(n_clients=10, s=5, local_steps=20, weighted=True,
                    swt=2.0, sit=1.0, slow_frac=0.5)
    lam = client_speeds(fed, 10)
    H = expected_steps(fed, lam)
    alg, *_ = _setup(fed)
    # eta_i * H_i is constant across clients (paper §3.3)
    prod = alg.eta_i * alg.H
    np.testing.assert_allclose(prod, prod[0], rtol=1e-5)


def test_h_can_be_zero():
    """Slow clients polled early can contribute zero steps (paper §2.2)."""
    fed = FedConfig(n_clients=16, s=16, local_steps=5, swt=0.1, sit=0.1,
                    slow_frac=1.0, lam_slow=0.01)
    alg, st, part, _, key = _setup(fed)
    st, m = alg.round(st, part, key)
    assert float(m["h_zero_frac"]) > 0.5


def test_bits_accounting_monotone():
    fed = FedConfig(n_clients=6, s=3, local_steps=1, bits=8)
    alg, st, part, _, key = _setup(fed)
    st1, m = alg.round(st, part, key)
    st2, _ = alg.round(st1, part, key)
    assert float(st2.bits_sent) == 2 * float(st1.bits_sent) > 0
    # lattice: (s+1) messages of d_pad*b (+ γ) bits per round
    assert float(m["bits"]) == (fed.s + 1) * alg.quant.message_bits(alg.d)


@pytest.mark.parametrize("mode", ["both", "server_only", "client_only"])
def test_averaging_variants_run(mode):
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.2)
    alg, st, part, test, key = _setup(fed, avg_mode=mode)
    for _ in range(10):
        key, sub = jax.random.split(key)
        st, _ = alg.round(st, part, sub)
    loss, _ = mlp_loss(alg.eval_params(st), test)
    assert np.isfinite(float(loss))
