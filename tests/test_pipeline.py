"""Rotated-space compression pipeline: equivalence, rotation audit, registry.

The pipeline (repro.compression.pipeline) restructures the QuAFL exchange so
each vector is rotated once per round. These tests pin it three ways:

  * a full ``QuAFL.round`` through the fused rotated-space path must match
    the per-message materialize-everything composition (same keys/noise/γ)
    to fp32 tolerance,
  * the trace-time rotation counter must report exactly s+1 forward and
    s+1 inverse full-model rotations per round (seed spent ~5s+1; the first
    fused version spent s+2 before the downlink became an elementwise
    quantize of the cached rotated server),
  * every registered backend must agree on codes and decodes
    (``perf_smoke``: the fast sanity slice CI runs on every commit).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import ExchangePipeline, get_backend, make_quantizer
from repro.compression.rotation import pad_len
from repro.configs.base import FedConfig
from repro.core import QuAFL
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def _setup(fed, seed=0, **kw):
    part, test = make_federated_classification(seed, fed.n_clients, d=16,
                                               n_classes=4)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), 16, 32, 4)
    alg = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0,
                batch_fn=lambda d_, k: client_batch(k, d_, 16), **kw)
    return alg, alg.init(params0), part


# ---------------------------------------------------------------------------
# equivalence: fused rotated-space round == per-message composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("avg_mode", ["both", "server_only", "client_only"])
def test_quafl_round_pipeline_matches_reference(avg_mode):
    fed = FedConfig(n_clients=8, s=4, local_steps=2, lr=0.2, bits=8)
    key = jax.random.PRNGKey(7)
    alg_p, st_p, part = _setup(fed, avg_mode=avg_mode)
    alg_r, st_r, _ = _setup(fed, avg_mode=avg_mode,
                            exchange_impl="reference")
    for _ in range(3):
        key, sub = jax.random.split(key)
        st_p, m_p = alg_p.round(st_p, part, sub)
        st_r, m_r = alg_r.round(st_r, part, sub)
    np.testing.assert_allclose(np.asarray(st_p.server),
                               np.asarray(st_r.server), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_p.clients),
                               np.asarray(st_r.clients), atol=2e-5)
    np.testing.assert_allclose(float(m_p["quant_err"]),
                               float(m_r["quant_err"]), rtol=1e-3)


def test_pipeline_exchange_matches_reference_directly():
    """quafl_round vs quafl_round_reference on raw vectors, both backends."""
    key = jax.random.PRNGKey(3)
    d, s = 5000, 6
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    ref = ExchangePipeline(bits=8, backend="jnp").quafl_round_reference(
        key, server, Y, hints)
    for backend in ("jnp", "pallas_interpret"):
        out = ExchangePipeline(bits=8, backend=backend).quafl_round(
            key, server, Y, hints)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref[0]),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(ref[1]),
                                   atol=2e-5)


# ---------------------------------------------------------------------------
# rotation audit: s+1 forward, s+1 inverse per round (seed: ~5s+1)
# ---------------------------------------------------------------------------

def test_rotation_count_per_round():
    s = 4
    fed = FedConfig(n_clients=8, s=s, local_steps=1, lr=0.1)
    alg, st, part = _setup(fed)
    assert alg.pipeline is not None
    alg.pipeline.stats.reset()
    st, _ = alg.round(st, part, jax.random.PRNGKey(0))   # one trace
    assert alg.pipeline.stats.fwd == s + 1, alg.pipeline.stats
    assert alg.pipeline.stats.inv == s + 1, alg.pipeline.stats
    # further rounds reuse the trace: the count is structural, per round
    alg.pipeline.stats.reset()
    st, _ = alg.round(st, part, jax.random.PRNGKey(1))
    assert alg.pipeline.stats.fwd == 0 and alg.pipeline.stats.inv == 0


# ---------------------------------------------------------------------------
# backend registry (perf_smoke: must stay well under a minute)
# ---------------------------------------------------------------------------

@pytest.mark.perf_smoke
def test_backend_registry_names():
    for name in ("jnp", "pallas_interpret", "pallas"):
        assert get_backend(name).name == name
    with pytest.raises(ValueError):
        get_backend("cuda")
    with pytest.raises(ValueError):
        make_quantizer("lattice", 8, backend="bogus").encode(
            jax.random.PRNGKey(0), jnp.ones(8), 1.0)


@pytest.mark.perf_smoke
@pytest.mark.parametrize("backend", ["jnp", "pallas_interpret"])
def test_backend_quantizer_roundtrip(backend):
    d = 3000
    q = make_quantizer("lattice", 8, backend=backend)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (d,))
    ref = x + 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    msg = q.encode(key, x, jnp.linalg.norm(x - ref))
    xh = q.decode(key, msg, ref)
    err = float(jnp.linalg.norm(xh - x))
    assert err <= float(msg.gamma) * np.sqrt(pad_len(d)) * 1.01


@pytest.mark.perf_smoke
def test_backends_agree_on_codes_and_decode():
    d = 3000
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (d,))
    ref = x + 0.02 * jax.random.normal(jax.random.fold_in(key, 1), (d,))
    hint = jnp.linalg.norm(x - ref)
    msgs, outs = {}, {}
    for backend in ("jnp", "pallas_interpret"):
        q = make_quantizer("lattice", 8, backend=backend)
        msgs[backend] = q.encode(key, x, hint)
        outs[backend] = q.decode(key, msgs[backend], ref)
    a, b = msgs["jnp"], msgs["pallas_interpret"]
    assert float(a.gamma) == float(b.gamma)
    # stochastic-rounding boundaries may flip under a different matmul
    # association; anything beyond a stray ulp-flip is a real bug
    agree = float(jnp.mean((a.codes == b.codes).astype(jnp.float32)))
    assert agree >= 0.999, agree
    np.testing.assert_allclose(np.asarray(outs["jnp"]),
                               np.asarray(outs["pallas_interpret"]),
                               atol=2.5 * float(a.gamma))


@pytest.mark.perf_smoke
def test_fedconfig_backend_reaches_pipeline():
    fed = FedConfig(n_clients=4, s=2, local_steps=1,
                    kernel_backend="pallas_interpret")
    alg, _, _ = _setup(fed)
    assert alg.pipeline.backend == "pallas_interpret"
    assert alg.quant.backend == "pallas_interpret"
