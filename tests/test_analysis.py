"""Gate tests for ``repro.analysis``: the real matrix is clean, and every
analyzer provably fires on a mutation fixture.

The clean half runs the SAME checks ``python -m repro.analysis.lint``
runs (jaxpr invariants for every registry algorithm × codec, rotation
op-budget, donation audit, recompile sentinel, AST rules over src/repro),
at the tiny lint config. The mutation half hand-builds a violating
program per rule — key reuse with distinct derivations, a host callback
in a traced body, a donated-but-unaliasable buffer, an f64 leak, a
mid-run retrace — and asserts the matching analyzer reports it.
"""
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.astlint import lint_source
from repro.analysis.donation import audit_lowered
from repro.analysis.jaxpr import (analyze_jaxpr, check_host_callbacks,
                                  check_key_discipline, check_wide_dtypes,
                                  op_counts)
from repro.analysis.lint import (MATRIX_CODECS, _build_cell, _cells,
                                 _traceable, analyze_cell, sentinel_run)
from repro.analysis.opbudget import (OpBudget, check_rotation_budget,
                                     rotation_budget)
from repro.analysis.sentinel import RecompileSentinel

# ---------------------------------------------------------------------------
# the real matrix is clean
# ---------------------------------------------------------------------------

# every registry algorithm (minus the python event-driven fedbuff) × codec
ALL_CELLS = sorted(set(_cells()))


@pytest.mark.parametrize("alg_name,codec",
                         ALL_CELLS, ids=[f"{a}x{c}" for a, c in ALL_CELLS])
def test_matrix_cell_trace_clean(alg_name, codec):
    """Host-callback / wide-dtype / key-discipline / op-budget checks pass
    on the traced round and scanned chunk of every real cell. Donation
    (a compile per cell) is covered on a subset below."""
    rep = analyze_cell(alg_name, codec, donation=False)
    assert rep["violations"] == [], rep["violations"]


@pytest.mark.parametrize("alg_name", ["quafl", "fedavg"])
def test_donation_audit_clean(alg_name):
    """The engine's scanned chunk donates every state leaf and XLA honors
    every donation (checked against the compiled executable's
    input_output_alias table)."""
    rep = analyze_cell(alg_name, "lattice", donation=True)
    assert rep["violations"] == [], rep["violations"]
    d = rep["donation"]
    assert d["donation_intent"] == d["state_leaves"]
    assert d["aliased"] == d["donation_intent"]


def test_sentinel_one_compile_per_chunk_length():
    """A scanned simulate() run compiles each chunk program exactly once —
    the recompile sentinel interrogates the engine's jit cache."""
    rep = sentinel_run("quafl")
    assert rep["violations"] == [], rep["violations"]
    assert rep["compiles"] == {"chunk2": 1}


def test_rotation_budget_via_opbudget_api():
    """The promoted op-budget audit reproduces the pipeline invariant:
    s+1 forward / s+1 inverse rotation passes per QuAFL round."""
    alg, data, params0, key = _build_cell("quafl", "lattice")
    state = alg.init(params0)
    assert check_rotation_budget(alg, state, data, key, "quafl") == []
    # and a wrong budget is reported, proving the check is live
    bad = check_rotation_budget(alg, state, data, key, "quafl",
                                budget={"rotation_fwd": 99})
    assert [v.rule for v in bad] == ["op-budget"]


def test_opbudget_legacy_surface():
    b = OpBudget()
    b.fwd += 3
    b.inv += 3
    assert b.counters == {"rotation_fwd": 3, "rotation_inv": 3}
    assert b.expect("x", rotation_budget(2)) == []   # s=2 -> 3 fwd / 3 inv
    b.reset()
    assert b.fwd == 0 and b.counters == {}


def test_ast_lint_clean_on_repo():
    import os
    from repro.analysis import astlint
    # src/repro (repro may be a namespace package without __file__)
    root = os.path.dirname(os.path.dirname(os.path.abspath(
        astlint.__file__)))
    viols = astlint.lint_path(root)
    assert viols == [], [v.as_dict() for v in viols]


# ---------------------------------------------------------------------------
# mutation fixtures: each analyzer provably fires
# ---------------------------------------------------------------------------

def test_mutation_key_reuse_detected():
    """One key consumed by two DISTINCT derivations is the schedule-
    corrupting bug; the same derivation twice (shared-dither idiom) and
    fold_in domain separation stay legal."""
    def bad(key):
        return jax.random.uniform(key, (8,)) + jax.random.normal(key, (4,)).sum()

    viols = check_key_discipline(jax.make_jaxpr(bad)(jax.random.PRNGKey(0)),
                                 "fixture")
    assert [v.rule for v in viols] == ["key-reuse"]

    def shared_dither(key):   # same derivation twice: legal by design
        return jax.random.uniform(key, (8,)) + jax.random.uniform(key, (8,))

    assert check_key_discipline(
        jax.make_jaxpr(shared_dither)(jax.random.PRNGKey(0)), "ok") == []

    def folded(key):          # fold_in is the canonical fix: legal
        return (jax.random.uniform(jax.random.fold_in(key, 1), (8,)).sum()
                + jax.random.normal(jax.random.fold_in(key, 2), (4,)).sum())

    assert check_key_discipline(
        jax.make_jaxpr(folded)(jax.random.PRNGKey(0)), "ok") == []


def test_mutation_key_reuse_across_scan_detected():
    """Reuse hiding across a scan boundary (key drawn outside AND consumed
    differently inside the body) is still caught."""
    def bad(key):
        x = jax.random.uniform(key, (8,))

        def body(c, _):
            # a DIFFERENT derivation ((4,) draw) of the key the outer
            # uniform already consumed with an (8,) draw
            return c + jax.random.normal(key, (4,)).sum(), None

        y, _ = jax.lax.scan(body, x, None, length=3)
        return y

    viols = check_key_discipline(jax.make_jaxpr(bad)(jax.random.PRNGKey(0)),
                                 "fixture")
    assert any(v.rule == "key-reuse" for v in viols)


def test_mutation_host_callback_detected():
    def bad(x):
        jax.debug.print("x = {}", x)
        return x * 2

    viols = check_host_callbacks(jax.make_jaxpr(bad)(jnp.ones(3)), "fixture")
    assert [v.rule for v in viols] == ["host-callback"]
    assert check_host_callbacks(
        jax.make_jaxpr(lambda x: x * 2)(jnp.ones(3)), "ok") == []


def test_mutation_f64_leak_detected():
    with jax.experimental.enable_x64():
        def bad(x):
            return x.astype(jnp.float64) * 2.0

        closed = jax.make_jaxpr(bad)(jnp.ones(3, jnp.float32))
    viols = check_wide_dtypes(closed, "fixture")
    assert [v.rule for v in viols] == ["wide-dtype"]


def test_mutation_donation_miss_detected():
    """A donated buffer no output can alias is a silent copy; the audit
    reports the dropped intent."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        # donated x is unused: jit records no donation intent for it
        f = jax.jit(lambda x, y: y * 2, donate_argnums=(0,))
        lowered = f.lower(jnp.ones(4), jnp.ones(3))
        viols = audit_lowered(lowered, 1, "fixture")
    assert "donation" in viols[0].rule
    # the clean case: donated input aliased 1:1 into the output
    g = jax.jit(lambda x: x + 1, donate_argnums=(0,))
    assert audit_lowered(g.lower(jnp.ones(4)), 1, "ok") == []


def test_mutation_recompile_detected():
    """Sentinel trips on (a) a traced program changing under one tag and
    (b) a jit cache holding two compilations of one chunk program."""
    s = RecompileSentinel()
    s.record("tag", jax.make_jaxpr(lambda x: x + 1)(jnp.ones(3)))
    s.record("tag", jax.make_jaxpr(lambda x: x * 2)(jnp.ones(3)))
    assert [v.rule for v in s.report()] == ["recompile"]

    class FakeEngine:
        _chunk_fns = {2: jax.jit(lambda s, d, k: s)}

    # two different input shapes -> two compilations in the cache
    FakeEngine._chunk_fns[2](jnp.ones(3), 0, 0)
    FakeEngine._chunk_fns[2](jnp.ones(4), 0, 0)
    viols = RecompileSentinel().check_engine("tag", FakeEngine())
    assert [v.rule for v in viols] == ["recompile"]


def test_mutation_op_budget_blown_detected():
    b = OpBudget()
    b.add("rotation_fwd", 5)
    b.add("rotation_inv", 3)
    viols = b.expect("fixture", rotation_budget(2))
    # fwd 5 != budgeted 3 is reported; inv 3 == 3 is clean
    assert [v.rule for v in viols] == ["op-budget"]
    assert "rotation_fwd" in viols[0].detail


def test_analyze_jaxpr_reports_tracked_ops():
    def f(x):
        return x.astype(jnp.int32).astype(jnp.float32)

    viols, rep = analyze_jaxpr(jax.make_jaxpr(f)(jnp.ones(3)), "x")
    assert viols == []
    assert rep["convert_element_type"] == 2
    assert rep["eqns_total"] >= 2
    assert op_counts(jax.make_jaxpr(f)(jnp.ones(3)))["convert_element_type"] == 2


def test_rs_transport_audit_clean_and_byte_gate_trips():
    """The fused shard_local_rs exchange, traced on an abstract (4, 2)
    mesh, moves integer codes + scalar γ rows over its all-gather and only
    scalar hints over psum — and the byte budget FAILS the fixture where
    the fp32 aggregate rides the wire instead."""
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.analysis.lint import rs_transport_audit
    from repro.analysis.opbudget import check_collective_bytes
    from repro.compression.codecs import resolve_codec
    from repro.compression.transports import transport_for_mode
    from repro.configs.base import FedConfig
    from repro.core.exchange_local import make_shardlocal_exchange

    rep = rs_transport_audit()
    assert rep["violations"] == []
    ops = rep["ops"]
    # the reducing phase is the ONE fp32-sized collective; the re-gather
    # is coded (ints) with a scalars-only float side channel
    assert ops["reduce_scatter_fbytes"] == (1 << 16) * 4
    assert 0 < ops["all_gather_ibytes"] <= (1 << 16)
    assert ops["all_gather_fbytes"] <= 64 * 4
    assert ops["psum_fbytes"] <= 4096

    # regression fixture: fp32 psum transport under the same budget
    n, d = 4, 1 << 16
    mesh = AbstractMesh((("data", n), ("model", 2)))
    fed = FedConfig(n_clients=n, s=n, bits=8,
                    codec_up="lattice_packed:bits=4",
                    codec_down="lattice_packed:bits=4")
    up = resolve_codec(None, fed, direction="up")
    dn = resolve_codec(None, fed, direction="down")
    ex = make_shardlocal_exchange(
        up, dn, mesh, {"w": P()}, {"w": P("data")}, "data", n,
        transport=transport_for_mode("shard_local"))
    closed = jax.make_jaxpr(ex)(
        {"w": jax.ShapeDtypeStruct((d,), jnp.float32)},
        {"w": jax.ShapeDtypeStruct((n, d), jnp.float32)},
        {"w": jax.ShapeDtypeStruct((n, d), jnp.float32)},
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    viols = check_collective_bytes(closed, "fixture", {
        "all_gather_fbytes": 64 * n, "psum_fbytes": 4096})
    assert [v.rule for v in viols] == ["collective-bytes"]
    assert "psum_fbytes" in viols[0].detail


# ---------------------------------------------------------------------------
# AST rule fixtures
# ---------------------------------------------------------------------------

def _rules(viols):
    return [v.rule for v in viols]


def test_ast_host_rng_in_traced_body():
    src = (
        "import numpy as np\n"
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x + np.random.rand()\n"
    )
    assert any(r.startswith("R001") for r in _rules(lint_source(src, "core/x.py")))
    # np.random OUTSIDE a traced body is fine (seeding, data gen)
    ok = "import numpy as np\ndef gen():\n    return np.random.rand()\n"
    assert lint_source(ok, "core/x.py") == []


def test_ast_host_time_in_traced_body():
    src = (
        "import time\n"
        "import jax\n"
        "def device_round(self, state, data, key):\n"
        "    t = time.time()\n"
        "    return state, {'t': t}\n"
    )
    assert any(r.startswith("R001")
               for r in _rules(lint_source(src, "fed/x.py")))


def test_ast_unresolvable_codec_spec():
    src = "cfg = FedConfig(n_clients=4, codec_up='no_such_codec:8')\n"
    assert any(r.startswith("R002") for r in _rules(lint_source(src, "x.py")))
    ok = "cfg = FedConfig(n_clients=4, codec_up='lattice:8')\n"
    assert lint_source(ok, "x.py") == []


def test_ast_metrics_keys_incomplete():
    src = (
        "def device_round(self, state, data, key):\n"
        "    metrics = {'sim_time': 0.0}\n"
        "    return state, metrics\n"
    )
    assert any(r.startswith("R003")
               for r in _rules(lint_source(src, "fed/x.py")))


def test_ast_unused_import():
    src = "import os\nimport sys\nprint(sys.argv)\n"
    viols = lint_source(src, "x.py")
    assert _rules(viols) == ["R004:unused-import"]
    assert "os" in viols[0].detail
    # noqa and __all__ re-exports are honored
    assert lint_source("import os  # noqa\n", "x.py") == []
    assert lint_source("import os\n__all__ = ['os']\n", "x.py") == []


# ---------------------------------------------------------------------------
# engine hooks used by the analyzers
# ---------------------------------------------------------------------------

def test_traced_hooks_are_side_effect_free():
    """traced_round/traced_chunk must not consume state or warm the run
    cache — the sentinel relies on fingerprinting before the run."""
    from repro.fed.engine import RoundEngine
    alg, data, params0, key = _build_cell("quafl", "lattice")
    eng = RoundEngine(_traceable(alg))
    state = eng.alg.init(params0)
    closed_r = eng.traced_round(state, data, key)
    closed_c = eng.traced_chunk(state, data, key, 2)
    assert closed_r.jaxpr.eqns and closed_c.jaxpr.eqns
    assert eng._chunk_fns == {}   # tracing never touched the jit cache
    # the state is still alive (not donated by tracing)
    _ = [leaf.block_until_ready()
         for leaf in jax.tree_util.tree_leaves(state)]


def test_matrix_covers_every_registry_algorithm():
    from repro.fed.registry import registered_algorithms
    algs = {a for a, _ in ALL_CELLS}
    assert algs == set(registered_algorithms()) - {"fedbuff"}
    assert set(MATRIX_CODECS) == {"lattice", "lattice_packed", "topk_ef"}
    # the heterogeneous-width cell rides quafl (the batched grouped path)
    assert ("quafl", "lattice_grouped") in ALL_CELLS


# ---------------------------------------------------------------------------
# flow engine + wire-truth / γ-interval / divergence analyzers
# ---------------------------------------------------------------------------

def test_collective_bytes_on_hand_built_jaxprs():
    """Byte accounting per collective on hand-built programs: reductions
    charge their input avals, gathers their output avals, split by element
    kind — and the walk reaches bodies nested under scan."""
    from repro.analysis.jaxpr import collective_bytes

    env = [("i", 4)]
    closed = jax.make_jaxpr(lambda x: jax.lax.psum(x, "i"),
                            axis_env=env)(jnp.ones(8, jnp.float32))
    assert collective_bytes(closed) == {"psum_fbytes": 8 * 4}

    closed = jax.make_jaxpr(lambda x: jax.lax.all_gather(x, "i"),
                            axis_env=env)(jnp.ones(16, jnp.int32))
    assert collective_bytes(closed) == {"all_gather_ibytes": 4 * 16 * 4}

    # lax.psum_scatter binds the reduce_scatter primitive — the byte gate
    # must charge that key, not a vacuous psum_scatter_* entry
    closed = jax.make_jaxpr(
        lambda x: jax.lax.psum_scatter(x, "i", tiled=True),
        axis_env=env)(jnp.ones(8, jnp.float32))
    assert collective_bytes(closed) == {"reduce_scatter_fbytes": 8 * 4}

    def scanned(x):
        def body(c, _):
            return c + jax.lax.psum(c, "i"), jax.lax.all_gather(c, "i")
        return jax.lax.scan(body, x, None, length=3)

    b = collective_bytes(jax.make_jaxpr(scanned, axis_env=env)(
        jnp.ones(8, jnp.float32)))
    assert b["psum_fbytes"] == 8 * 4
    assert b["all_gather_fbytes"] == 4 * 8 * 4


def test_flow_engine_scan_carry_fixpoint():
    """The worklist engine iterates scan carries to a fixpoint: a carry
    clamped into [0, 1] every iteration keeps that interval instead of
    widening to top."""
    from repro.analysis.intervals import interval_of

    def f(x):
        def body(c, _):
            return jnp.clip(c * 0.5, 0.0, 1.0), None

        y, _ = jax.lax.scan(body, x, None, length=8)
        return y

    (iv,) = interval_of(f, [(0.0, 1.0)], jnp.zeros(4))
    assert 0.0 <= iv[0] and iv[1] <= 1.0


def test_mutation_fp32_wire_leak_detected():
    """An fp32 array marked as the int codes payload is the wire-leak bug
    class: the audit flags kind AND container drift; the honest container
    at the same site is clean."""
    from repro.analysis.provenance import wire_mark
    from repro.analysis.wire import check_wire_truth
    from repro.compression.codecs import LatticeCodec

    codec = LatticeCodec(bits=8)
    d = 2048
    decl = codec.wire_declaration(d)

    def leaky(x):
        return wire_mark(x, channel="up", part="codes", codec=codec.name,
                         d=d)

    closed = jax.make_jaxpr(leaky)(jnp.ones(d, jnp.float32))
    viols = check_wire_truth(closed, where="fixture", decl_up=decl,
                             codec_up=codec, d=d)
    assert any("fp32 reaching the wire" in v.detail for v in viols)
    assert any("32-bit container" in v.detail for v in viols)

    def honest(x):
        return wire_mark(x.astype(jnp.uint8), channel="up", part="codes",
                         codec=codec.name, d=d)

    closed = jax.make_jaxpr(honest)(jnp.ones(d, jnp.float32))
    assert check_wire_truth(closed, where="ok", decl_up=decl,
                            codec_up=codec, d=d) == []


def test_grouped_levels_row_audited_not_exempted():
    """The grouped codec's per-message moduli row is charged wire traffic:
    the declaration carries a levels part (message_bits includes it), the
    traced row passes the audit — and a declaration WITHOUT the part trips
    the uncharged-side-channel rule."""
    from repro.analysis.provenance import wire_mark
    from repro.analysis.wire import check_wire_truth
    from repro.compression.codecs import GroupedLatticeCodec, WireDecl

    codec = GroupedLatticeCodec(bits_per_client=(4, 8),
                                wire_width_per_client=(4, 8))
    d = 1024
    decl = codec.wire_declaration(d)
    assert decl.part("levels") is not None
    assert decl.message_bits == codec.message_bits(d)
    assert decl.moduli == (16, 256)

    def ships(codes, gam, lev):
        wire_mark(codes, channel="up", part="codes", codec=codec.name,
                  batched=True, d=d)
        wire_mark(gam, channel="up", part="gamma", codec=codec.name,
                  batched=True, d=d)
        wire_mark(lev, channel="up", part="levels", codec=codec.name,
                  batched=True, d=d)
        return codes

    closed = jax.make_jaxpr(ships)(jnp.zeros((2, d), jnp.uint8),
                                   jnp.zeros((2,), jnp.float32),
                                   jnp.zeros((2,), jnp.float32))
    assert check_wire_truth(closed, where="ok", decl_up=decl) == []

    bald = WireDecl(codec=codec.name,
                    parts=tuple(p for p in decl.parts
                                if p.part != "levels"),
                    moduli=decl.moduli, safety=decl.safety)
    viols = check_wire_truth(closed, where="fixture", decl_up=bald)
    assert any("side-channel" in v.detail for v in viols)


def test_mutation_gamma_overflow_detected():
    """Interval analysis proves the encode path cannot wrap at the
    declared width — and fires when codes overflow the modulus or the
    safety factor is too small for Lemma 3.1's window."""
    from repro.analysis.intervals import (check_encode_intervals,
                                          check_gamma_window)
    from repro.compression.pipeline import ExchangePipeline, LatticeWire

    pipe = ExchangePipeline(bits=8, backend="jnp")
    wire8 = LatticeWire(bits=8, pack=1)
    assert check_encode_intervals(pipe, wire8, 2048, (256,), "ok") == []
    # 8-bit codes audited against a declared 4-bit modulus: overflow
    viols = check_encode_intervals(pipe, wire8, 2048, (16,), "fixture")
    assert [v.rule for v in viols] == ["gamma-overflow"]

    assert check_gamma_window(pipe, wire8, 2048, "ok") == []
    loose = ExchangePipeline(bits=8, backend="jnp", safety=1.5)
    viols = check_gamma_window(loose, wire8, 2048, "fixture")
    assert viols and all(v.rule == "gamma-overflow" for v in viols)


def test_mutation_divergent_escape_detected():
    """A value derived from axis_index committed through P() is device 0's
    copy published as replicated state; resolving it with a psum over the
    axis is clean."""
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.analysis.divergence import check_divergence
    from repro.utils.compat import shard_map

    mesh = AbstractMesh((("data", 4),))

    def body(x):
        return x + jax.lax.axis_index("data").astype(jnp.float32)

    bad = shard_map(body, mesh=mesh, in_specs=P(), out_specs=P(),
                    check_vma=False)
    viols = check_divergence(jax.make_jaxpr(bad)(jnp.ones(8)), "fixture")
    assert [v.rule for v in viols] == ["spmd-divergence"]
    assert "data" in viols[0].detail

    def resolved(x):
        return jax.lax.psum(
            x + jax.lax.axis_index("data").astype(jnp.float32), "data")

    ok = shard_map(resolved, mesh=mesh, in_specs=P(), out_specs=P(),
                   check_vma=False)
    assert check_divergence(jax.make_jaxpr(ok)(jnp.ones(8)), "ok") == []


def test_exchange_matrix_cells_clean():
    """Every codec × transport pair of the shard-local exchange passes the
    wire-truth, byte-budget, divergence and γ_rs checks on the abstract
    pod mesh."""
    from repro.analysis.lint import _exchange_cells, analyze_exchange_cell
    for codec, transport in _exchange_cells():
        rep = analyze_exchange_cell(codec, transport, d=1 << 14, n=4)
        assert rep["violations"] == [], (codec, transport,
                                         rep["violations"])


def test_engine_wire_provenance_hook():
    alg, data, params0, key = _build_cell("quafl", "lattice")
    from repro.fed.engine import RoundEngine
    t = _traceable(alg)
    closed, marks, colls = RoundEngine(t).wire_provenance(
        t.init(params0), data, key)
    assert closed.jaxpr.eqns
    parts = {p.get("part") for p, _, _ in marks}
    assert {"codes", "gamma"} <= parts
    assert all(p.get("d", 0) > 0 for p, _, _ in marks)


def test_lint_cell_listing_and_loud_only():
    from repro.analysis.lint import list_cells, run_lint
    cells = list_cells()
    assert "quaflxlattice_grouped" in cells
    assert "exchange:latticexreduce_scatter" in cells
    assert "rs_transport" in cells
    with pytest.raises(SystemExit):
        run_lint(quick=True, only="definitely_not_a_cell", verbose=False)


def test_report_is_deterministic_schema_v2():
    """The committed report must be byte-stable: schema v2, no wall-clock
    keys anywhere — timings go to the side dict the caller owns."""
    import json
    from repro.analysis.lint import run_lint
    timings = {}
    rep = run_lint(quick=True, only="sequentialxlattice", verbose=False,
                   timings=timings)
    assert rep["schema"] == "analysis.v2"
    assert '"seconds"' not in json.dumps(rep)
    assert rep["violations_total"] == 0
    assert timings and "total" in timings
