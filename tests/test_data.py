"""Federated data pipeline properties."""
import jax
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # seed container has no hypothesis wheel
    from _hypothesis_fallback import given, settings, st

from repro.data import (gaussian_mixture, lm_token_stream,
                        make_federated_classification, partition_by_class,
                        partition_iid)


@settings(deadline=None, max_examples=10)
@given(n_clients=st.integers(2, 16), seed=st.integers(0, 100))
def test_partition_iid_disjoint_cover(n_clients, seed):
    key = jax.random.PRNGKey(seed)
    data = gaussian_mixture(key, 64 * n_clients, d=8, n_classes=4)
    part = partition_iid(key, data, n_clients)
    assert part["x"].shape[0] == n_clients
    # flattened sample set sizes add up and rows are unique
    xs = np.asarray(part["x"]).reshape(-1, 8)
    assert len(np.unique(xs.round(5), axis=0)) == xs.shape[0]


def test_partition_by_class_label_skew():
    """Non-iid split: each client sees a strict subset of classes."""
    key = jax.random.PRNGKey(0)
    data = gaussian_mixture(key, 4000, d=8, n_classes=10)
    part = partition_by_class(key, data, 10, 10)
    for i in range(10):
        labels = np.unique(np.asarray(part["y"][i]))
        assert len(labels) <= 3  # heavy concentration vs 10 classes


def test_label_distributions_differ_vs_iid():
    key = jax.random.PRNGKey(1)
    data = gaussian_mixture(key, 2000, d=8, n_classes=10)
    iid = partition_iid(key, data, 8)
    non = partition_by_class(key, data, 8, 10)

    def spread(part):
        hists = [np.bincount(np.asarray(part["y"][i]), minlength=10)
                 for i in range(8)]
        hists = np.stack(hists) / np.maximum(
            np.stack(hists).sum(1, keepdims=True), 1)
        return float(np.std(hists, axis=0).mean())

    assert spread(non) > 3 * spread(iid)


def test_lm_token_stream_ranges_and_noniid():
    key = jax.random.PRNGKey(2)
    a = lm_token_stream(key, 4, 64, 1000, client_id=0)
    b = lm_token_stream(key, 4, 64, 1000, client_id=1)
    assert a.shape == (4, 64)
    assert int(a.min()) >= 0 and int(a.max()) < 1000
    # different clients see permuted marginals
    assert not bool(jnp.all(a == b))


def test_make_federated_classification_shapes():
    part, test = make_federated_classification(0, 6, samples_per_client=32,
                                               d=8, n_classes=4)
    assert part["x"].shape == (6, 32, 8)
    assert test["x"].shape[0] == 1024
