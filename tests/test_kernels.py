"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hadamard import hadamard_blocks
from repro.kernels.lattice_quant import lattice_decode, lattice_encode
from repro.kernels.ops import rotate_pallas
from repro.compression.rotation import rotate


@pytest.mark.parametrize("n,r,c", [(1, 128, 128), (3, 128, 128),
                                   (4, 64, 64), (2, 128, 64), (7, 16, 16)])
def test_hadamard_kernel_shapes(n, r, c):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, r, c))
    out = hadamard_blocks(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.hadamard_ref(x)), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hadamard_kernel_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128)).astype(dtype)
    out = hadamard_blocks(x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.hadamard_ref(x.astype(jnp.float32))),
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4)


def test_rotate_pallas_matches_jnp_rotation():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (50_000,))
    np.testing.assert_allclose(np.asarray(rotate_pallas(x, key)),
                               np.asarray(rotate(x, key)), atol=1e-4)
    y = rotate_pallas(x, key)
    np.testing.assert_allclose(
        np.asarray(rotate_pallas(y, key, inverse=True)[:50_000]),
        np.asarray(x), atol=1e-4)


@pytest.mark.parametrize("d,bits", [(1024, 4), (8192, 8), (4096, 12),
                                    (65536, 8)])
def test_lattice_kernels_match_ref(d, bits):
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(key, (d,)) * 2.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), (d,))
    gamma = 0.02
    codes = lattice_encode(y, u, gamma, bits=bits)
    codes_ref = ref.lattice_encode_ref(y, u, gamma, bits)
    assert bool(jnp.all(codes == codes_ref))
    w = y + 0.001 * jax.random.normal(jax.random.fold_in(key, 2), (d,))
    out = lattice_decode(codes, w, gamma, bits=bits)
    out_ref = ref.lattice_decode_ref(codes_ref, w, gamma, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-6)
    # end-to-end: reconstruction within γ per coordinate
    assert float(jnp.max(jnp.abs(out - y))) <= gamma * 1.001


@pytest.mark.parametrize(
    "b,t,h,kv,dh,window,cap",
    [(2, 256, 4, 2, 64, 0, 0.0),      # GQA causal
     (1, 512, 8, 8, 32, 0, 0.0),      # MHA long
     (1, 256, 8, 2, 64, 128, 0.0),    # sliding window
     (2, 128, 4, 1, 64, 0, 50.0),     # MQA + softcap (gemma)
     (1, 256, 4, 2, 128, 64, 30.0)])  # window + softcap
def test_flash_attention_sweep(b, t, h, kv, dh, window, cap):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, softcap=cap,
                          block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                  softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_flash_attention_matches_model_attention():
    """The kernel is a drop-in for the model's chunked sdpa path."""
    from repro.configs.base import LayerSpec
    from repro.configs import get_reduced
    from repro.models.attention import attention_prefill
    cfg = get_reduced("llama3.2-1b")
    spec = LayerSpec()
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 3)
    b, t = 1, 256
    q = jax.random.normal(ks[0], (b, t, cfg.n_heads, cfg.head_dim))
    k = jax.random.normal(ks[1], (b, t, cfg.n_kv_heads, cfg.head_dim))
    v = jax.random.normal(ks[2], (b, t, cfg.n_kv_heads, cfg.head_dim))
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, block_q=64, block_k=64)),
        np.asarray(attention_prefill(cfg, spec, q, k, v)), atol=2e-5)
