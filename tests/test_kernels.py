"""Pallas kernels vs pure-jnp oracles (interpret mode): shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.exchange import (fused_decode, fused_encode, fused_rotate,
                                    snap_codes)
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hadamard import hadamard_blocks
from repro.kernels.lattice_quant import lattice_decode, lattice_encode
from repro.kernels.ops import rotate_pallas
from repro.compression.rotation import _signs, pad_len, rotate


@pytest.mark.parametrize("n,r,c", [(1, 128, 128), (3, 128, 128),
                                   (4, 64, 64), (2, 128, 64), (7, 16, 16)])
def test_hadamard_kernel_shapes(n, r, c):
    x = jax.random.normal(jax.random.PRNGKey(0), (n, r, c))
    out = hadamard_blocks(x)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.hadamard_ref(x)), atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hadamard_kernel_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 128)).astype(dtype)
    out = hadamard_blocks(x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.hadamard_ref(x.astype(jnp.float32))),
        atol=1e-1 if dtype == jnp.bfloat16 else 1e-4)


def test_rotate_pallas_matches_jnp_rotation():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (50_000,))
    np.testing.assert_allclose(np.asarray(rotate_pallas(x, key)),
                               np.asarray(rotate(x, key)), atol=1e-4)
    y = rotate_pallas(x, key)
    np.testing.assert_allclose(
        np.asarray(rotate_pallas(y, key, inverse=True)[:50_000]),
        np.asarray(x), atol=1e-4)


@pytest.mark.parametrize("d,bits", [(1024, 4), (8192, 8), (4096, 12),
                                    (65536, 8)])
def test_lattice_kernels_match_ref(d, bits):
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(key, (d,)) * 2.0
    u = jax.random.uniform(jax.random.fold_in(key, 1), (d,))
    gamma = 0.02
    codes = lattice_encode(y, u, gamma, bits=bits)
    codes_ref = ref.lattice_encode_ref(y, u, gamma, bits)
    assert bool(jnp.all(codes == codes_ref))
    w = y + 0.001 * jax.random.normal(jax.random.fold_in(key, 2), (d,))
    out = lattice_decode(codes, w, gamma, bits=bits)
    out_ref = ref.lattice_decode_ref(codes_ref, w, gamma, bits)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-6)
    # end-to-end: reconstruction within γ per coordinate
    assert float(jnp.max(jnp.abs(out - y))) <= gamma * 1.001


# ---------------------------------------------------------------------------
# fused exchange kernels (batched) vs per-message oracles
# d values include non-multiples of the 16384 rotation block (padding edges)
# ---------------------------------------------------------------------------

def _oracle_rows(d, s, key):
    """(s, d) messages + shared signs/noise/per-row gammas + oracle rotate."""
    d_pad = pad_len(d)
    krot = jax.random.fold_in(key, 0)
    signs = _signs(krot, d_pad)
    x = jax.random.normal(jax.random.fold_in(key, 1), (s, d)) * 2.0
    u = jax.random.uniform(jax.random.fold_in(key, 2), (s, d_pad))
    gammas = 0.01 * (1.0 + jnp.arange(s, dtype=jnp.float32))
    y_rows = jnp.stack([rotate(x[i], krot) for i in range(s)])
    return x, u, gammas, signs, krot, y_rows


@pytest.mark.parametrize("d,s,bits", [(1000, 3, 4), (5000, 4, 8),
                                      (20000, 2, 16), (16384, 5, 8)])
def test_fused_encode_matches_vmapped_oracle(d, s, bits):
    key = jax.random.PRNGKey(10)
    x, u, gammas, signs, krot, y_rows = _oracle_rows(d, s, key)
    d_pad = pad_len(d)
    x_pad = jnp.pad(x, ((0, 0), (0, d_pad - d)))
    y_rot, codes = fused_encode(x_pad, signs, u, gammas, bits=bits,
                                want_rotated=True)
    codes_ref = jnp.stack([
        ref.lattice_encode_ref(y_rows[i], u[i], gammas[i], bits)
        for i in range(s)])
    np.testing.assert_allclose(np.asarray(y_rot), np.asarray(y_rows),
                               atol=1e-4)
    assert float(jnp.mean((codes == codes_ref).astype(jnp.float32))) == 1.0


@pytest.mark.parametrize("d,s,bits", [(1000, 3, 4), (5000, 4, 8),
                                      (20000, 2, 16)])
def test_snap_codes_matches_vmapped_oracle(d, s, bits):
    key = jax.random.PRNGKey(11)
    x, u, gammas, signs, krot, y_rows = _oracle_rows(d, s, key)
    codes = jnp.stack([ref.lattice_encode_ref(y_rows[i], u[i], gammas[i],
                                              bits) for i in range(s)])
    w = y_rows[0:1] + 0.001   # shared rotated reference, broadcast over s
    out = snap_codes(codes, w, gammas, bits=bits)
    exp = jnp.stack([ref.lattice_decode_ref(codes[i], w[0], gammas[i], bits)
                     for i in range(s)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-6)


@pytest.mark.parametrize("d,s,bits", [(1000, 3, 4), (5000, 4, 8),
                                      (20000, 2, 16)])
def test_fused_decode_matches_composed_oracle(d, s, bits):
    """One broadcast message decoded against s references == per-row
    rotate-ref / snap / inverse-rotate composition."""
    key = jax.random.PRNGKey(12)
    x, u, gammas, signs, krot, y_rows = _oracle_rows(d, s, key)
    d_pad = pad_len(d)
    gamma = gammas[0:1]
    codes = ref.lattice_encode_ref(y_rows[0], u[0], gamma[0], bits)[None]
    refs = x[0][None] + 0.002 * jax.random.normal(
        jax.random.fold_in(key, 3), (s, d))
    refs_pad = jnp.pad(refs, ((0, 0), (0, d_pad - d)))
    out = fused_decode(codes, refs_pad, signs, gamma, bits=bits)[:, :d]
    exp = jnp.stack([
        rotate(ref.lattice_decode_ref(codes[0], rotate(refs[i], krot),
                                      gamma[0], bits),
               krot, inverse=True)[:d]
        for i in range(s)])
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_fused_rotate_roundtrip_batched():
    d, s = 50_000, 3
    key = jax.random.PRNGKey(13)
    signs = _signs(key, pad_len(d))
    x = jax.random.normal(jax.random.fold_in(key, 1), (s, d))
    x_pad = jnp.pad(x, ((0, 0), (0, pad_len(d) - d)))
    y = fused_rotate(x_pad, signs)
    np.testing.assert_allclose(
        np.asarray(jnp.stack([rotate(x[i], key) for i in range(s)])),
        np.asarray(y), atol=1e-4)
    back = fused_rotate(y, signs, inverse=True)[:, :d]
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


@pytest.mark.parametrize(
    "b,t,h,kv,dh,window,cap",
    [(2, 256, 4, 2, 64, 0, 0.0),      # GQA causal
     (1, 512, 8, 8, 32, 0, 0.0),      # MHA long
     (1, 256, 8, 2, 64, 128, 0.0),    # sliding window
     (2, 128, 4, 1, 64, 0, 50.0),     # MQA + softcap (gemma)
     (1, 256, 4, 2, 128, 64, 30.0)])  # window + softcap
def test_flash_attention_sweep(b, t, h, kv, dh, window, cap):
    key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, t, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, kv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, kv, dh), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, softcap=cap,
                          block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v, causal=True, window=window,
                                  softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=2e-5)


def test_flash_attention_bf16():
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 128, 2, 64)).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, block_q=64, block_k=64)
    exp = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=3e-2)


def test_flash_attention_matches_model_attention():
    """The kernel is a drop-in for the model's chunked sdpa path."""
    from repro.configs.base import LayerSpec
    from repro.configs import get_reduced
    from repro.models.attention import attention_prefill
    cfg = get_reduced("llama3.2-1b")
    spec = LayerSpec()
    key = jax.random.PRNGKey(6)
    ks = jax.random.split(key, 3)
    b, t = 1, 256
    q = jax.random.normal(ks[0], (b, t, cfg.n_heads, cfg.head_dim))
    k = jax.random.normal(ks[1], (b, t, cfg.n_kv_heads, cfg.head_dim))
    v = jax.random.normal(ks[2], (b, t, cfg.n_kv_heads, cfg.head_dim))
    np.testing.assert_allclose(
        np.asarray(flash_attention(q, k, v, block_q=64, block_k=64)),
        np.asarray(attention_prefill(cfg, spec, q, k, v)), atol=2e-5)
