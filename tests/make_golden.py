"""Regenerate tests/golden_pr3.npz — the PR 3 bit-for-bit anchor.

Runs a deterministic 3-round slice of the core registry algorithms through
the PUBLIC API (make_algorithm + round) and stores the resulting server
vectors plus the per-round bit counters. The committed .npz was produced by
the PR 3 tree, BEFORE the codec/transport redesign: the redesigned default
path (``lattice`` codec both directions) must reproduce it exactly, which is
what ``tests/test_codecs.py::test_default_lattice_matches_pr3_golden`` pins.

    PYTHONPATH=src python tests/make_golden.py
"""
import jax
import numpy as np

from repro.configs.base import FedConfig
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.fed import make_algorithm
from repro.models.mlp import init_mlp_classifier, mlp_loss
from repro.utils.tree import tree_flatten_vector

GOLDEN = {
    "quafl": dict(),
    "quafl_scaffold": dict(),
    "fedavg": dict(),
    "fedbuff_device": dict(buffer_size=2, quantize=True,
                           quantizer="lattice"),
}


def main(path="tests/golden_pr3.npz"):
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.3, bits=8)
    part, _ = make_federated_classification(0, fed.n_clients, d=16,
                                            n_classes=4)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), 16, 32, 4)
    bf = lambda dd, k: client_batch(k, dd, 16)
    out = {}
    for name, kw in GOLDEN.items():
        alg = make_algorithm(name, fed, loss_fn=mlp_loss, template=params0,
                             batch_fn=bf, **kw)
        state = alg.init(params0)
        key = jax.random.PRNGKey(7)
        ups, downs = [], []
        for _ in range(3):
            key, sub = jax.random.split(key)
            state, m = alg.round(state, part, sub)
            ups.append(float(m["bits_up"]))
            downs.append(float(m["bits_down"]))
        out[f"{name}/server"] = np.asarray(
            tree_flatten_vector(alg.eval_params(state)))
        out[f"{name}/bits_up"] = np.asarray(ups)
        out[f"{name}/bits_down"] = np.asarray(downs)
    np.savez(path, **out)
    print(f"wrote {len(out)} arrays to {path}")


if __name__ == "__main__":
    main()
