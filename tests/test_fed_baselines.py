"""Baselines the paper compares against: FedAvg, FedBuff, sequential."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FedAvg, FedBuff, Sequential
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss


def _setup(fed, seed=0):
    part, test = make_federated_classification(seed, fed.n_clients, d=16,
                                               n_classes=4)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), 16, 32, 4)
    bf = lambda d, k: client_batch(k, d, 16)
    return part, test, params0, bf


def test_fedavg_converges_and_waits_for_slowest():
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3)
    part, test, params0, bf = _setup(fed)
    alg = FedAvg(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    st = alg.init(params0)
    key = jax.random.PRNGKey(1)
    for _ in range(40):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
    loss, metr = mlp_loss(alg.eval_params(st), test)
    assert float(metr["acc"]) > 0.6
    # round time must exceed the expected K steps of a FAST client — the
    # synchronous server waits for stragglers
    assert float(st.sim_time) / 40 > fed.local_steps / fed.lam_fast


def test_fedbuff_runs_and_improves():
    fed = FedConfig(n_clients=8, s=4, local_steps=4, lr=0.3)
    part, test, params0, bf = _setup(fed)
    alg = FedBuff(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf,
                  buffer_size=4, server_lr=0.5)
    hist = alg.run(params0, part, jax.random.PRNGKey(2), total_time=600.0,
                   eval_every=100.0,
                   eval_fn=lambda p: float(mlp_loss(p, test)[0]))
    assert len(hist) >= 4
    assert hist[-1][1] < hist[0][1]


def test_fedbuff_quantized():
    fed = FedConfig(n_clients=6, s=3, local_steps=2, lr=0.2, bits=8)
    part, test, params0, bf = _setup(fed)
    alg = FedBuff(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf,
                  buffer_size=3, quantize=True)
    hist = alg.run(params0, part, jax.random.PRNGKey(3), total_time=300.0,
                   eval_every=100.0,
                   eval_fn=lambda p: float(mlp_loss(p, test)[0]))
    assert np.isfinite(hist[-1][1])


def test_sequential_baseline():
    fed = FedConfig(n_clients=4, s=1, local_steps=1, lr=0.2)
    part, test, params0, bf = _setup(fed)
    alg = Sequential(fed=fed, loss_fn=mlp_loss, template=params0, batch_fn=bf)
    st = alg.init(params0)
    key = jax.random.PRNGKey(4)
    l0 = float(mlp_loss(alg.eval_params(st), test)[0])
    for _ in range(150):
        key, sub = jax.random.split(key)
        st, _ = alg.round(st, part, sub)
    assert float(mlp_loss(alg.eval_params(st), test)[0]) < l0
