import jax
import pytest

# Smoke tests and benches must see exactly ONE device — the 512-device flag
# is set only inside repro.launch.dryrun (and the sharding tests' subprocess).


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
