"""The Pallas flash-attention kernel as a drop-in for the model's prefill
path: full model forward with USE_FLASH_KERNEL must match the jnp path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import forward, init_lm
from repro.models import attention as A


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b", "olmo-1b"])
def test_forward_with_flash_kernel_matches(arch):
    cfg = get_reduced(arch)
    if arch == "gemma2-2b":
        # reduced gemma2 window is 64 < t: exercises the sliding flash path
        pass
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0,
                              cfg.vocab_size)
    ref, _, _ = forward(cfg, params, {"tokens": toks})
    A.USE_FLASH_KERNEL = True
    try:
        out, _, _ = forward(cfg, params, {"tokens": toks})
    finally:
        A.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-2)


def test_flash_fallback_on_chunked():
    """llama4 chunked-local layers must silently fall back to the jnp path."""
    cfg = get_reduced("llama4-scout-17b-a16e")
    params, _ = init_lm(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 128), 0,
                              cfg.vocab_size)
    ref, _, _ = forward(cfg, params, {"tokens": toks})
    A.USE_FLASH_KERNEL = True
    try:
        out, _, _ = forward(cfg, params, {"tokens": toks})
    finally:
        A.USE_FLASH_KERNEL = False
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-3,
                               rtol=2e-2)
