"""Correctness of the §Perf variants: shard_map MoE grouped matmul and the
shard-local quantized exchange must match their baselines numerically."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest


SUBPROC_MOE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models.model import init_lm, forward
from repro.models import moe as moe_mod
from repro.utils.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("llama4-scout-17b-a16e").replace(
    d_ff=256, vocab_size=512)
key = jax.random.PRNGKey(0)
params, _ = init_lm(cfg, key)
toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
with mesh:
    ref, _, _ = jax.jit(lambda p, t: forward(cfg, p, {"tokens": t})[0])(
        params, toks), None, None
    moe_mod.set_moe_mesh(mesh)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, impl="ragged_shmap"))
    out, _, _ = jax.jit(lambda p, t: forward(cfg2, p, {"tokens": t})[0])(
        params, toks), None, None
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4,
                           rtol=2e-3)
print("MOE_SHMAP_OK")
"""

SUBPROC_EXCHANGE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import get_reduced
from repro.configs.base import FedConfig, ShapeConfig
from repro.launch.steps import build_train_step, init_train_state
from repro.utils.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
cfg = get_reduced("llama3.2-1b").replace(n_heads=8, n_kv_heads=2)
fed = FedConfig(local_steps=2, lr=0.05, bits=8)
shape = ShapeConfig("tiny", 16, 8, "train")
with mesh:
    for tr in ("shard_local", "shard_local_codes"):
        step, spec, sh = build_train_step(cfg, fed, mesh, shape,
                                          fed_mode="client_dp", transport=tr)
        st = init_train_state(cfg, jax.random.PRNGKey(0), 4)
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 2, 2, 16), 0,
                                  cfg.vocab_size)
        st2, m = jax.jit(step, in_shardings=sh)(
            st, {"tokens": toks}, jax.random.key_data(jax.random.PRNGKey(2)))
        assert not bool(jnp.isnan(st2.server["embed/tok"]).any()), tr
        assert float(m["quant_err_sq"]) > 0, tr
print("EXCHANGE_OK")
"""


def _run(code):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=900)


def test_moe_shmap_matches_ragged_8dev():
    r = _run(SUBPROC_MOE)
    assert "MOE_SHMAP_OK" in r.stdout, r.stdout + r.stderr


def test_shardlocal_exchange_8dev():
    r = _run(SUBPROC_EXCHANGE)
    assert "EXCHANGE_OK" in r.stdout, r.stdout + r.stderr


def test_bf16_score_partials_close():
    """The (refuted-for-perf) bf16-partials switch must stay numerically
    sane — it remains a user-facing flag."""
    from repro.models import attention as A
    from repro.configs import get_reduced
    from repro.configs.base import LayerSpec
    cfg = get_reduced("llama3.2-1b")
    spec = LayerSpec()
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.bfloat16)
    ref = A.attention_prefill(cfg, spec, q, k, v)
    A.BF16_SCORE_PARTIALS = True
    try:
        out = A.attention_prefill(cfg, spec, q, k, v)
    finally:
        A.BF16_SCORE_PARTIALS = False
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=5e-2)
