from repro.serving.engine import ServeEngine, Request  # noqa: F401
