"""Batched serving engine over the model zoo's prefill/decode steps.

Static-batch continuous serving: requests queue up, the engine assembles a
batch (padding prompts to a common length), prefills once, then decodes
token-by-token with the jitted single-token step until every sequence hits
its max_new_tokens or emits EOS. Serves the SERVER model of a QuAFL run —
serving is inference of the federated result (DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.model import decode_step, forward, init_cache


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    eos_id: int = -1            # -1: never stop early
    out_tokens: List[int] = field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8,
                 max_seq: int = 256, temperature: float = 0.0):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.queue: List[Request] = []

        def _decode(params, tok, pos, cache, key):
            logits, cache = decode_step(cfg, params, tok, pos, cache)
            lg = logits[:, -1]
            if temperature > 0:
                nxt = jax.random.categorical(key, lg / temperature, axis=-1)
            else:
                nxt = jnp.argmax(lg, axis=-1)
            return nxt.astype(jnp.int32), cache

        self._decode = jax.jit(_decode)

    @classmethod
    def from_algorithm(cls, cfg: ModelConfig, alg, state, **kw):
        """Serve the server model of ANY federated run: ``alg`` is a
        :class:`repro.fed.FedAlgorithm` and ``state`` its final state —
        ``eval_params`` is the protocol's one door to the trained model, so
        every registry algorithm (and every future one) is servable the
        same way."""
        return cls(cfg, alg.eval_params(state), **kw)

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill(self, prompts: np.ndarray):
        cache = init_cache(self.cfg, prompts.shape[0], self.max_seq)
        logits, cache, _ = forward(self.cfg, self.params,
                                   {"tokens": jnp.asarray(prompts)},
                                   cache=cache, write_pos=0)
        return logits[:, -1], cache

    def run(self, key=None) -> List[Request]:
        """Serve everything in the queue; returns completed requests."""
        key = key if key is not None else jax.random.PRNGKey(0)
        done: List[Request] = []
        while self.queue:
            batch = self.queue[: self.max_batch]
            self.queue = self.queue[self.max_batch:]
            plen = max(len(r.prompt) for r in batch)
            prompts = np.zeros((len(batch), plen), np.int32)
            for i, r in enumerate(batch):
                prompts[i, -len(r.prompt):] = r.prompt  # left-pad with 0
            last_logits, cache = self._prefill(prompts)
            if self.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, last_logits / self.temperature, axis=-1)
            else:
                tok = jnp.argmax(last_logits, axis=-1)
            tok = tok.astype(jnp.int32)
            alive = np.ones(len(batch), bool)
            steps = max(r.max_new_tokens for r in batch)
            for i, r in enumerate(batch):
                r.out_tokens.append(int(tok[i]))
            pos = plen
            for _ in range(min(steps - 1, self.max_seq - plen - 1)):
                key, sub = jax.random.split(key)
                tok, cache = self._decode(self.params, tok[:, None],
                                          jnp.int32(pos), cache, sub)
                pos += 1
                for i, r in enumerate(batch):
                    if not alive[i]:
                        continue
                    t = int(tok[i])
                    if len(r.out_tokens) < r.max_new_tokens:
                        r.out_tokens.append(t)
                    if t == r.eos_id or len(r.out_tokens) >= r.max_new_tokens:
                        alive[i] = False
                if not alive.any():
                    break
            done.extend(batch)
        return done
