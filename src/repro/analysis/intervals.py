"""γ-overflow interval analysis over the fused encode path.

Lemma 3.1's wrap condition is the repo's central numerical contract: a
snapped code recovers the right lattice point only while the decode
reference stays within half a wrap window (``levels·γ/2``) of the encoded
vector. The γ derivation (``wrap_gamma`` + fp32 floor in
``ExchangePipeline.gammas``) is *designed* to guarantee that, but nothing
previously checked the shipped code against the design — a wrong safety
factor, a levels row exceeding the declared modulus, or a γ taken from a
stale hint would silently corrupt snapped codes.

This module proves the contract by abstract interpretation with intervals
(:class:`IntervalDomain` on the flow engine), on the SAME traced
derivations the exchange runs:

* :func:`check_encode_intervals` — traces ``pipeline.quantize`` (the
  rotate→scale→round→wrap path, pre-packing) and proves the emitted codes
  cannot exceed the codec's DECLARED per-message moduli. ``jnp.mod`` is
  summarised precisely through a ``remainder`` call override, so the codes
  interval is [0, L_traced]; a pipeline quantizing at 8 bits under a
  4-bit declaration fails here.
* :func:`check_gamma_window` — traces the wrap margin
  ``L/2 − (coord_bound(dist)/γ + 1)`` through the real ``gammas``
  derivation over a ladder of hint bands ``[h, 2h]`` spanning 2^-20..2^20,
  with the encoded distance bounded by the band's own hint (the protocol
  contract: hints upper-bound ‖Y−X‖). A positive lower bound on every
  band proves no wrap overflow at any scale; with band ratio 2 the proof
  obligation is ``L/2 − L/safety − 1 > 0`` — true for every registry wire
  (safety 8, bits ≥ 2), false e.g. for safety < 2.3.
* :func:`check_rs_gamma_window` — the same margin proof through
  :func:`repro.core.exchange_local.rs_gamma`, whose triangle-inequality
  hint sum (``h_sum = Σᵢ‖QYᵢ − rot(X_t)‖ ≥ ‖ΣQYᵢ − n·rot(X_t)‖``) bounds
  the scatter-resident aggregate; bands are ``[n·h, 2n·h]``.
"""

from __future__ import annotations

import math
from typing import List, Tuple

import numpy as np

from repro.analysis.flow import FlowDomain, analyze_flow
from repro.analysis.jaxpr import Violation

Interval = Tuple[float, float]

TOP: Interval = (-math.inf, math.inf)

# hint ladder: powers of two, each analyzed as the band [h, 2h] so
# consecutive bands tile every positive hint scale
LADDER_LO, LADDER_HI = -20, 20


def _iv(lo: float, hi: float) -> Interval:
    return (float(lo), float(hi))


def _mul_iv(a: Interval, b: Interval) -> Interval:
    def prod(x, y):
        if x == 0.0 or y == 0.0:  # avoid 0 * inf -> nan
            return 0.0
        return x * y
    ps = [prod(a[0], b[0]), prod(a[0], b[1]), prod(a[1], b[0]),
          prod(a[1], b[1])]
    return _iv(min(ps), max(ps))


def _div_iv(a: Interval, b: Interval) -> Interval:
    if b[0] <= 0.0 <= b[1]:
        return TOP
    def quot(x, y):
        q = x / y if not (math.isinf(x) and math.isinf(y)) else 0.0
        return 0.0 if math.isnan(q) else q
    qs = [quot(a[0], b[0]), quot(a[0], b[1]), quot(a[1], b[0]),
          quot(a[1], b[1])]
    return _iv(min(qs), max(qs))


def _monotone(f, a: Interval) -> Interval:
    return _iv(f(a[0]), f(a[1]))


def _aval_size(aval) -> int:
    shape = getattr(aval, "shape", ())
    return int(np.prod(shape)) if shape else 1


class IntervalDomain(FlowDomain):
    """(lo, hi) bounds per value; arrays carry one interval over all
    elements (sound: every element lies inside)."""

    def top(self, aval) -> Interval:
        return TOP

    def join(self, a: Interval, b: Interval) -> Interval:
        return _iv(min(a[0], b[0]), max(a[1], b[1]))

    def literal(self, lit) -> Interval:
        return self.const(lit.aval, lit.val)

    def const(self, aval, val) -> Interval:
        try:
            arr = np.asarray(val)
            if arr.dtype == bool:
                return _iv(float(arr.min()), float(arr.max()))
            if not np.issubdtype(arr.dtype, np.number):
                return TOP
            return _iv(float(arr.min()), float(arr.max()))
        except (TypeError, ValueError):
            return TOP

    def call_override(self, eqn, closed_sub, ins) -> List[Interval] | None:
        # jnp.mod lowers to pjit[name=remainder] around rem + sign-fix
        # select_n; the composite's mathematical result is [0, divisor)
        # when the divisor is positive — far tighter than its body.
        if eqn.params.get("name") == "remainder" and len(ins) == 2:
            div = ins[1]
            if div[0] > 0.0:
                return [_iv(0.0, div[1])]
        return None

    def transfer(self, eqn, ins: List[Interval]) -> List[Interval]:
        rule = _RULES.get(eqn.primitive.name)
        if rule is None:
            return [TOP for _ in eqn.outvars]
        out = rule(eqn, ins)
        return [out for _ in eqn.outvars]


def _first(eqn, ins):
    return ins[0]


def _join_all(eqn, ins):
    out = ins[0]
    for v in ins[1:]:
        out = _iv(min(out[0], v[0]), max(out[1], v[1]))
    return out


def _bool01(eqn, ins):
    return _iv(0.0, 1.0)


def _convert(eqn, ins):
    a = ins[0]
    dtype = np.dtype(eqn.outvars[0].aval.dtype)
    if np.issubdtype(dtype, np.integer) and math.isfinite(a[0]) \
            and math.isfinite(a[1]):
        # conversion truncates toward zero: always within [floor, ceil]
        return _iv(math.floor(a[0]), math.ceil(a[1]))
    return a


def _clamp(eqn, ins):
    lo_b, x, hi_b = ins
    lo = max(lo_b[0], min(x[0], hi_b[1]))
    hi = min(hi_b[1], max(x[1], lo_b[0]))
    return _iv(lo, hi)


def _abs_iv(eqn, ins):
    a = ins[0]
    if a[0] <= 0.0 <= a[1]:
        return _iv(0.0, max(-a[0], a[1]))
    lo, hi = abs(a[0]), abs(a[1])
    return _iv(min(lo, hi), max(lo, hi))


def _sqrt_iv(eqn, ins):
    a = ins[0]
    return _iv(math.sqrt(max(a[0], 0.0)),
               math.sqrt(a[1]) if a[1] >= 0.0 else 0.0)


def _rsqrt_iv(eqn, ins):
    a = ins[0]
    if a[0] <= 0.0:
        return TOP
    return _iv(1.0 / math.sqrt(a[1]), 1.0 / math.sqrt(a[0]))


def _log_iv(eqn, ins):
    a = ins[0]
    hi = math.log(a[1]) if a[1] > 0.0 else -math.inf
    lo = math.log(a[0]) if a[0] > 0.0 else -math.inf
    return _iv(lo, hi)


def _exp_iv(eqn, ins):
    return _monotone(lambda v: math.exp(min(v, 700.0)), ins[0])


def _sign_iv(eqn, ins):
    a = ins[0]
    return _iv(-1.0 if a[0] < 0.0 else 0.0 if a[0] == 0.0 else 1.0,
               1.0 if a[1] > 0.0 else 0.0 if a[1] == 0.0 else -1.0)


def _ipow(eqn, ins):
    a, y = ins[0], int(eqn.params["y"])
    if y < 0:
        return _div_iv(_iv(1.0, 1.0), _ipow_pos(a, -y))
    return _ipow_pos(a, y)


def _ipow_pos(a: Interval, y: int) -> Interval:
    if y % 2 == 1:
        return _iv(a[0] ** y, a[1] ** y)
    lo = 0.0 if a[0] <= 0.0 <= a[1] else min(abs(a[0]), abs(a[1])) ** y
    return _iv(lo, max(abs(a[0]), abs(a[1])) ** y)


def _rem_iv(eqn, ins):
    num, div = ins
    if div[0] > 0.0:
        if num[0] >= 0.0:  # lax.rem takes the dividend's sign
            return _iv(0.0, div[1])
        return _iv(-div[1], div[1])
    return TOP


def _reduce_sum(eqn, ins):
    n = _aval_size(eqn.invars[0].aval) // max(_aval_size(eqn.outvars[0].aval), 1)
    return _mul_iv(ins[0], _iv(n, n))


def _dot(eqn, ins):
    ((lhs_c, _), _) = eqn.params["dimension_numbers"]
    shape = eqn.invars[0].aval.shape
    n = 1
    for dim in lhs_c:
        n *= int(shape[dim])
    return _mul_iv(_mul_iv(ins[0], ins[1]), _iv(n, n))


def _iota(eqn, ins):
    shape = eqn.outvars[0].aval.shape
    dim = eqn.params.get("dimension", 0)
    hi = int(shape[dim]) - 1 if shape else 0
    return _iv(0.0, max(hi, 0))


def _pad_iv(eqn, ins):
    return _join_all(eqn, ins[:2])


_RULES = {
    # structural / value-preserving
    "reshape": _first, "transpose": _first, "squeeze": _first,
    "broadcast_in_dim": _first, "slice": _first, "dynamic_slice": _first,
    "rev": _first, "copy": _first, "gather": _first, "stop_gradient": _first,
    "reduce_precision": _first, "expand_dims": _first,
    "concatenate": _join_all, "pad": _pad_iv,
    # select_n joins its cases (the predicate operand is excluded)
    "select_n": lambda eqn, ins: _join_all(eqn, ins[1:]),
    # arithmetic
    "add": lambda eqn, ins: _iv(ins[0][0] + ins[1][0], ins[0][1] + ins[1][1]),
    "sub": lambda eqn, ins: _iv(ins[0][0] - ins[1][1], ins[0][1] - ins[1][0]),
    "mul": lambda eqn, ins: _mul_iv(ins[0], ins[1]),
    "div": lambda eqn, ins: _div_iv(ins[0], ins[1]),
    "neg": lambda eqn, ins: _iv(-ins[0][1], -ins[0][0]),
    "abs": _abs_iv, "sign": _sign_iv,
    "max": lambda eqn, ins: _iv(max(ins[0][0], ins[1][0]),
                                max(ins[0][1], ins[1][1])),
    "min": lambda eqn, ins: _iv(min(ins[0][0], ins[1][0]),
                                min(ins[0][1], ins[1][1])),
    "clamp": _clamp,
    "sqrt": _sqrt_iv, "rsqrt": _rsqrt_iv, "exp": _exp_iv, "log": _log_iv,
    "integer_pow": _ipow, "rem": _rem_iv,
    "convert_element_type": _convert,
    "tanh": lambda eqn, ins: _iv(-1.0, 1.0),
    "sin": lambda eqn, ins: _iv(-1.0, 1.0),
    "cos": lambda eqn, ins: _iv(-1.0, 1.0),
    "logistic": lambda eqn, ins: _iv(0.0, 1.0),
    # predicates / boolean algebra
    "lt": _bool01, "le": _bool01, "gt": _bool01, "ge": _bool01,
    "eq": _bool01, "ne": _bool01, "and": _bool01, "or": _bool01,
    "xor": _bool01, "not": _bool01, "is_finite": _bool01,
    "reduce_and": _bool01, "reduce_or": _bool01,
    # reductions / contractions
    "reduce_sum": _reduce_sum, "cumsum": _reduce_sum,
    "reduce_max": _first, "reduce_min": _first, "cummax": _first,
    "cummin": _first, "dot_general": _dot, "iota": _iota,
    "argmax": _iota, "argmin": _iota,
}

# floor/ceil of an infinite bound: keep the infinite side as-is
_RULES["floor"] = lambda eqn, ins: _iv(
    math.floor(ins[0][0]) if math.isfinite(ins[0][0]) else ins[0][0],
    math.floor(ins[0][1]) if math.isfinite(ins[0][1]) else ins[0][1])
_RULES["ceil"] = lambda eqn, ins: _iv(
    math.ceil(ins[0][0]) if math.isfinite(ins[0][0]) else ins[0][0],
    math.ceil(ins[0][1]) if math.isfinite(ins[0][1]) else ins[0][1])
_RULES["round"] = lambda eqn, ins: _iv(
    float(np.rint(ins[0][0])) if math.isfinite(ins[0][0]) else ins[0][0],
    float(np.rint(ins[0][1])) if math.isfinite(ins[0][1]) else ins[0][1])


def interval_of(fn, seeds: List[Interval], *example_args) -> List[Interval]:
    """Trace ``fn`` on the example arguments and bound its outputs given
    per-argument input intervals."""
    import jax
    closed = jax.make_jaxpr(fn)(*example_args)
    res = analyze_flow(closed, IntervalDomain(), inputs=list(seeds))
    return res.out_vals


def _ladder():
    return [2.0 ** k for k in range(LADDER_LO, LADDER_HI + 1)]


def check_encode_intervals(pipe, wire, d: int, declared_moduli,
                           where: str) -> List[Violation]:
    """Prove the traced quantize path cannot emit codes past the codec's
    declared moduli (pre-packing: sub-byte packing is a pure relayout of
    in-range codes)."""
    import jax.numpy as jnp
    from repro.compression.pipeline import LatticeWire
    from repro.compression.rotation import pad_len

    if not declared_moduli:
        return []
    out: List[Violation] = []
    d_pad = pad_len(d, pipe.block)
    unpacked = LatticeWire(bits=wire.bits, pack=1, levels=wire.levels)
    fn = lambda y, u, g: pipe.quantize(y, u, g, unpacked)  # noqa: E731
    ex = (jnp.zeros((2, d_pad)), jnp.zeros((2, d_pad)), jnp.zeros((2,)))
    # wrap is scale-free: any finite coords / positive γ band
    seeds = [_iv(-1e30, 1e30), _iv(0.0, 1.0), _iv(1e-12, 1e30)]
    codes = interval_of(fn, seeds, *ex)[0]
    l_max = float(max(declared_moduli))
    if codes[0] < 0.0 or codes[1] > l_max:
        out.append(Violation(
            "gamma-overflow", where,
            f"traced codes interval [{codes[0]:g}, {codes[1]:g}] escapes "
            f"the declared moduli (max {l_max:g}): wire values can wrap "
            f"past the charged width"))
    return out


def _window_margin_violations(margin_fn, example, bands, where: str,
                              what: str) -> List[Violation]:
    out = []
    for h in bands:
        # hint band [h, 2h]; the true distance is protocol-bounded by the
        # hint, so dist ∈ [0, 2h]; the fp32-floor norm is free
        seeds = [_iv(h, 2.0 * h), _iv(0.0, 2.0 * h), _iv(0.0, 1e30)]
        m = interval_of(margin_fn, seeds, *example)[0]
        if not (m[0] > 0.0):
            out.append(Violation(
                "gamma-overflow", where,
                f"{what}: wrap margin lower bound {m[0]:g} <= 0 on hint "
                f"band [{h:g}, {2 * h:g}] — snapped codes can wrap past "
                f"the window"))
            break  # one band suffices; the derivation is scale-uniform
    return out


def check_gamma_window(pipe, wire, d: int, where: str) -> List[Violation]:
    """Prove Lemma 3.1's wrap condition through the pipeline's own γ
    derivation, at every hint scale."""
    import jax.numpy as jnp
    from repro.compression.pipeline import coord_bound
    from repro.compression.rotation import pad_len

    d_pad = pad_len(d, pipe.block)

    def margin(hint, dist, xnorm):
        g = pipe.gammas(hint, xnorm, d, wire)
        levels = (jnp.asarray(wire.levels, jnp.float32)
                  if wire.levels is not None else 2.0 ** wire.bits)
        return levels / 2.0 - (coord_bound(dist, d_pad) / g + 1.0)

    ex = (jnp.ones(()), jnp.ones(()), jnp.ones(()))
    return _window_margin_violations(margin, ex, _ladder(), where,
                                     f"bits={wire.bits} safety={pipe.safety}")


def check_rs_gamma_window(pipe, wire_dn, d: int, n_clients: int,
                          where: str) -> List[Violation]:
    """The same wrap proof for the reduce-scatter aggregate downlink: γ_rs
    comes from the triangle-inequality hint sum over ``n_clients``, so the
    hint bands are the summed scale ``[n·h, 2n·h]``."""
    import jax.numpy as jnp
    from repro.compression.pipeline import coord_bound
    from repro.compression.rotation import pad_len
    from repro.core.exchange_local import rs_gamma

    d_pad = pad_len(d, pipe.block)

    def margin(h_sum, dist, nrm):
        g, wire_rs = rs_gamma(pipe, wire_dn, h_sum, nrm, d)
        return (2.0 ** wire_rs.bits) / 2.0 \
            - (coord_bound(dist, d_pad) / g[0] + 1.0)

    ex = (jnp.ones(()), jnp.ones(()), jnp.ones(()))
    bands = [n_clients * h for h in _ladder()]
    return _window_margin_violations(
        margin, ex, bands, where,
        f"rs bits={wire_dn.bits} n={n_clients} safety={pipe.safety}")
