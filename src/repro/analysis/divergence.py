"""SPMD divergence detection on the abstract mesh.

``shard_map`` gives every device its own python-identical program over
different data; a value inside the body is *divergent* over a mesh axis
when devices along that axis may hold different values. Committing such a
value through an output spec that does not carry the axis (``P()`` —
"replicated") silently publishes device 0's copy: state that should be a
cross-client aggregate becomes one client's local value. That bug class is
invisible to tests that only check shapes/finiteness — this analyzer makes
it a gate violation.

:class:`DivergenceDomain` runs on the flow engine with values =
``frozenset`` of mesh axis names a value may vary over (∅ = replicated;
the distinguished ``"*"`` = unknown provenance, treated as varying over
everything):

* entering a ``shard_map``, each body input varies over the axes its
  ``in_names`` shard it along (different devices see different blocks);
* ``axis_index(a)`` introduces variance over ``a``; ``psum``/``pmax``/
  ``pmin``/``all_gather`` *remove* the reduced/gathered axes (every device
  ends with the same aggregate); ``psum_scatter`` and ``ppermute`` keep or
  introduce the axis (devices end with different shards);
* everything else joins its operands (set union) — sound for elementwise
  and structural ops;
* exiting the ``shard_map``, an output still varying over an axis that its
  ``out_names`` entry does not carry is reported as a divergence escape.

:func:`check_divergence` wraps the run and returns the violations.
"""

from __future__ import annotations

from typing import FrozenSet, List

from repro.analysis.flow import FlowContext, JoinAllDomain, analyze_flow
from repro.analysis.jaxpr import Violation

Axes = FrozenSet[str]

_UNKNOWN = "*"

# collectives that make their result identical across the named axes
_RESOLVING = {"psum", "pmax", "pmin", "all_gather", "all_reduce"}
# collectives whose result still differs per device along the axis
_SHARDING = {"psum_scatter", "reduce_scatter", "ppermute"}


def _eqn_axes(eqn) -> Axes:
    ax = eqn.params.get("axes", None)
    if ax is None:
        ax = eqn.params.get("axis_name", ())
    if isinstance(ax, (str, int)):
        ax = (ax,)
    return frozenset(str(a) for a in ax)


def _names_axes(names_entry) -> Axes:
    """Mesh axes mentioned by one in_names/out_names dict entry
    ``{array_dim: (axis, ...)}``."""
    out = set()
    for axes in dict(names_entry).values():
        if isinstance(axes, (str, int)):
            out.add(str(axes))
        else:
            out.update(str(a) for a in axes)
    return frozenset(out)


class DivergenceDomain(JoinAllDomain):
    """May-vary axes per value; join = union."""

    def top(self, aval) -> Axes:
        return frozenset({_UNKNOWN})

    def bottom(self) -> Axes:
        return frozenset()

    def join(self, a: Axes, b: Axes) -> Axes:
        return a | b

    def transfer(self, eqn, ins: List[Axes]) -> List[Axes]:
        name = eqn.primitive.name
        if name == "axis_index":
            return [frozenset({str(eqn.params["axis_name"])})
                    for _ in eqn.outvars]
        if name in _RESOLVING:
            resolved = _eqn_axes(eqn)
            return [v - resolved for v in ins][:len(eqn.outvars)] \
                or [self.bottom() for _ in eqn.outvars]
        if name in _SHARDING:
            extra = _eqn_axes(eqn)
            return [v | extra for v in ins][:len(eqn.outvars)] \
                or [extra for _ in eqn.outvars]
        return super().transfer(eqn, ins)

    def enter_shard_map(self, eqn, ins: List[Axes]) -> List[Axes]:
        in_names = eqn.params["in_names"]
        return [v | _names_axes(spec) for v, spec in zip(ins, in_names)]

    def exit_shard_map(self, eqn, outs: List[Axes],
                       ctx: FlowContext) -> List[Axes]:
        out_names = eqn.params["out_names"]
        mesh_axes = frozenset(str(a) for a in eqn.params["mesh"].axis_names)
        mapped = []
        for i, (v, spec) in enumerate(zip(outs, out_names)):
            carried = _names_axes(spec)
            escaped = (v & (mesh_axes | {_UNKNOWN})) - carried
            if escaped:
                what = ("unknown-provenance value" if _UNKNOWN in escaped
                        else f"value varying over mesh axes "
                             f"{sorted(escaped)}")
                ctx.facts.append(Violation(
                    "spmd-divergence", ctx.where,
                    f"shard_map output {i} commits a {what} through "
                    f"out_names {dict(spec) or 'P()'} — device 0's copy "
                    f"is silently published as replicated state"))
            # outside the mesh the committed value is what the spec says
            mapped.append(v - mesh_axes - {_UNKNOWN})
        return mapped


def check_divergence(closed, where: str) -> List[Violation]:
    """Flag divergent values escaping any ``shard_map`` in ``closed`` as
    replicated state. Top-level inputs are global (replicated) arrays."""
    dom = DivergenceDomain()
    inputs = [dom.bottom() for _ in closed.jaxpr.invars]
    ctx = FlowContext(path=(where,))
    res = analyze_flow(closed, dom, inputs=inputs, ctx=ctx)
    return [f for f in ctx.facts if isinstance(f, Violation)]
