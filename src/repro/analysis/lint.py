"""``python -m repro.analysis.lint`` — the repo's static-analysis gate.

Runs both halves of :mod:`repro.analysis` and writes a machine-readable
``ANALYSIS.json``:

* **jaxpr matrix** — every registry algorithm × {lattice, lattice_packed,
  topk_ef} uplink codec is built at a tiny config, its round and scanned
  chunk traced through :meth:`RoundEngine.traced_round` / ``traced_chunk``,
  and checked for host callbacks, wide dtypes, key discipline, the
  rotation op-budget, and the donation contract of the compiled chunk;
  a scanned ``simulate()`` run per algorithm feeds the recompile sentinel
  (one compile per (algorithm, chunk length)).
* **AST rules** — :func:`repro.analysis.astlint.lint_path` over
  ``src/repro/``.
* **rs transport byte budget** — the fused ``shard_local_rs`` exchange is
  traced on an abstract (4, 2) mesh and its per-device collective payload
  audited (:func:`rs_transport_audit`): the redistribution all-gather must
  move integer codes + scalar γ rows, never the fp32 aggregate.

Exit status is the number of violations (0 = clean). Flags::

    --json PATH      where to write the report (default: repo-root
                     ANALYSIS.json; "-" to skip writing)
    --quick          skip the donation compiles and sentinel runs (the two
                     expensive passes) — trace-level + AST checks only
    --only SUBSTR    filter matrix cells by substring (e.g. --only quafl,
                     --only lattice_packed)

Registering a new analyzer = writing a function returning
``List[Violation]`` and appending it in :func:`analyze_cell` (jaxpr-level)
or :func:`repro.analysis.astlint.lint_source` (source-level); the README
"Static analysis" section walks through it.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

# algorithm × codec matrix ---------------------------------------------------

MATRIX_CODECS = ("lattice", "lattice_packed", "topk_ef")

# per-algorithm construction kwargs at the tiny lint config
_ALG_KWARGS = {"fedbuff_device": {"buffer_size": 2}}

# sparse EF uplink composes with every algorithm; the fused lattice
# downlink families also run the downlink direction
_DOWNLINK_OK = ("lattice", "lattice_packed")


def _cells(only: Optional[str] = None):
    from repro.fed.registry import registered_algorithms
    algs = [a for a in registered_algorithms() if a != "fedbuff"]
    for alg in algs:
        for codec in MATRIX_CODECS:
            cell = f"{alg}x{codec}"
            if only and only not in cell:
                continue
            yield alg, codec


def _build_cell(alg_name: str, codec: str):
    """Build (alg, params0, data, key) at the tiny lint config."""
    import jax
    from repro.configs.base import FedConfig
    from repro.fed.registry import make_algorithm
    down = codec if codec.split(":")[0] in _DOWNLINK_OK else ""
    kw = dict(_ALG_KWARGS.get(alg_name, {}))
    if alg_name == "spmd":
        from functools import partial
        from repro.configs import get_reduced
        from repro.data.synthetic import federated_token_task
        from repro.models.model import init_lm, lm_loss
        cfg = get_reduced("llama3.2-1b")
        fed = FedConfig(n_clients=1, s=1, local_steps=1, lr=0.02,
                        codec_up=codec, codec_down=down)
        params0, _ = init_lm(cfg, jax.random.PRNGKey(0))
        data, batch_fn = federated_token_task(0, 1, 32, 2, 16,
                                              cfg.vocab_size)
        alg = make_algorithm("spmd", fed, loss_fn=partial(lm_loss, cfg),
                             template=params0, batch_fn=batch_fn, cfg=cfg,
                             batch=2, seq=16, **kw)
        return alg, data, params0, jax.random.PRNGKey(1)
    from repro.data import make_federated_classification
    from repro.data.synthetic import client_batch
    from repro.models.mlp import init_mlp_classifier, mlp_loss
    d, hidden, classes = 16, 16, 4
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2, bits=8,
                    codec_up=codec, codec_down=down)
    part, _ = make_federated_classification(0, fed.n_clients, d=d,
                                            n_classes=classes)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), d, hidden,
                                     classes)
    alg = make_algorithm(alg_name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=lambda dd, k: client_batch(k, dd, d),
                         **kw)
    return alg, part, params0, jax.random.PRNGKey(1)


def _traceable(alg):
    """The (algorithm, init-state) pair the engine hooks trace. An
    algorithm with custom ``scan_rounds`` host control (adaptive bit-width)
    is analyzed through its current-bits inner algorithm."""
    inner_of = getattr(alg, "_alg", None)
    if callable(getattr(alg, "scan_rounds", None)) and callable(inner_of):
        return inner_of(int(alg.fed.bits))
    return alg


def analyze_cell(alg_name: str, codec: str, *, donation: bool = True,
                 chunk: int = 2) -> Dict:
    """All jaxpr-level checks for one (algorithm, codec) cell."""
    from repro.analysis.donation import audit_engine_chunk, donation_report
    from repro.analysis.jaxpr import analyze_jaxpr
    from repro.analysis.opbudget import (measure_round_counters,
                                         rotation_budget)
    from repro.fed.engine import RoundEngine
    cell = f"{alg_name}x{codec}"
    alg, data, params0, key = _build_cell(alg_name, codec)
    target = _traceable(alg)
    state = target.init(params0)
    eng = RoundEngine(target)

    viols = []
    closed_r = eng.traced_round(state, data, key)
    vs, ops = analyze_jaxpr(closed_r, f"{cell}/round")
    viols += vs
    closed_c = eng.traced_chunk(state, data, key, chunk)
    vs, ops_chunk = analyze_jaxpr(closed_c, f"{cell}/chunk{chunk}")
    viols += vs

    report: Dict = {"ops_round": ops, "ops_chunk": ops_chunk}
    # measure ONCE: a second trace of the same (self, avals) signature hits
    # the pjit trace cache and the python body (where the counters live)
    # never re-runs
    measured = measure_round_counters(target, state, data, key)
    if measured is not None:
        report["rotation_counters"] = dict(measured.counters)
        # the s+1/s+1 budget binds algorithms that route through the fused
        # rotated exchange; an inherited-but-unused pipeline (scaffold runs
        # stateless codec encodes instead) legitimately counts zero
        if any(measured.counters.values()):
            viols += measured.expect(f"{cell}/round",
                                     rotation_budget(int(target.fed.s)))
    if donation:
        viols += audit_engine_chunk(eng, state, data, key, chunk,
                                    f"{cell}/chunk{chunk}")
        report["donation"] = donation_report(eng, state, data, key, chunk)
    report["violations"] = [v.as_dict() for v in viols]
    return report


def sentinel_run(alg_name: str, *, rounds: int = 4, chunk: int = 2,
                 codec: str = "lattice") -> Dict:
    """Prove one-compile-per-(algorithm, chunk length) on a real scanned
    ``simulate()`` run: record the chunk fingerprint before the run, run,
    re-record, then interrogate every engine jit cache."""
    import jax
    from repro.analysis.sentinel import RecompileSentinel
    from repro.fed.simulate import simulate
    alg, data, params0, key = _build_cell(alg_name, codec)
    target = _traceable(alg)
    sentinel = RecompileSentinel()
    tag = f"{alg_name}x{codec}"

    from repro.fed.engine import RoundEngine
    pre = RoundEngine(target).traced_chunk(target.init(params0), data,
                                           jax.random.PRNGKey(1), chunk)
    sentinel.record((tag, chunk), pre)
    simulate(alg, params0, data, jax.random.PRNGKey(2), rounds=rounds,
             eval_every=0, scan_chunk=chunk)
    engines = [("", e) for e in [getattr(alg, "_round_engine", None)]
               if e is not None]
    # an adaptive wrapper compiles one program per visited bit-width: same
    # one-compile contract, separate tag per width (the width the pre-run
    # fingerprint pinned keeps the bare tag)
    engines += [("" if b == int(alg.fed.bits) else f"@bits{b}", e)
                for b, e in getattr(alg, "_engines", {}).items()]
    compiles = {}
    for subtag, eng in engines:
        sentinel.check_engine((tag + subtag, chunk), eng)
        if not callable(getattr(eng.alg, "device_round", None)):
            # engine over a custom-scan_rounds wrapper (adaptive): its
            # chunk cache is never populated — the inner engines above
            # carry the compiled programs — and it has nothing to trace
            continue
        post = eng.traced_chunk(eng.alg.init(params0), data,
                                jax.random.PRNGKey(1), chunk)
        sentinel.record((tag + subtag, chunk), post)
        for length, fn in eng._chunk_fns.items():
            try:
                compiles[f"chunk{length}{subtag}"] = fn._cache_size()
            except AttributeError:
                pass
    return {"violations": [v.as_dict() for v in sentinel.report()],
            "compiles": compiles}


def rs_transport_audit(d: int = 1 << 16, n: int = 4) -> Dict:
    """Trace the fused ``shard_local_rs`` exchange on an ABSTRACT (4, 2)
    data×model mesh (no devices needed — ``AbstractMesh`` + ``make_jaxpr``
    trace the same shard_map program a pod runs) and budget its per-device
    collective payload:

      * the redistribution ``all_gather`` must move integer codes plus
        scalar f32 γ rows only — a regression back to the fp32 re-gather
        (``all_gather_fbytes`` jumping from a handful of scalars to d·4)
        fails the gate,
      * no full-size fp32 ``psum`` may sneak back in either (the
        exact-psum fallback silently replacing the coded path on a
        shardable chunk would show up as ``psum_fbytes`` ≈ d·4).

    The reducing phase (``psum_scatter`` of the snapped fp32 chunks) is
    the one collective that legitimately moves d·4 float bytes.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.analysis.jaxpr import analyze_jaxpr
    from repro.analysis.opbudget import check_collective_bytes
    from repro.compression.codecs import resolve_codec
    from repro.compression.transports import transport_for_mode
    from repro.configs.base import FedConfig
    from repro.core.exchange_local import make_shardlocal_exchange

    mesh = AbstractMesh((("data", n), ("model", 2)))
    fed = FedConfig(n_clients=n, s=n, bits=8,
                    codec_up="lattice_packed:bits=4",
                    codec_down="lattice_packed:bits=4")
    up = resolve_codec(None, fed, direction="up")
    dn = resolve_codec(None, fed, direction="down")
    ex = make_shardlocal_exchange(
        up, dn, mesh, {"w": P()}, {"w": P("data")}, "data", n,
        transport=transport_for_mode("shard_local_rs"))
    srv = {"w": jax.ShapeDtypeStruct((d,), jnp.float32)}
    cl = {"w": jax.ShapeDtypeStruct((n, d), jnp.float32)}
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    closed = jax.make_jaxpr(ex)(srv, cl, cl, key)

    where = "shard_local_rs/exchange@mesh(4,2)"
    viols, ops = analyze_jaxpr(closed, where)
    # scalar side-channel budget: γ rows + hint psums are O(n) f32 words
    # per leaf; the uplink codes ride the all_gather as (packed) ints
    viols += check_collective_bytes(closed, where, {
        "all_gather_fbytes": 64 * n,
        "psum_fbytes": 4096,
        "all_gather_ibytes": d,
    })
    return {"ops": ops, "violations": [v.as_dict() for v in viols]}


def run_lint(*, quick: bool = False, only: Optional[str] = None,
             donation: Optional[bool] = None,
             sentinel: Optional[bool] = None, verbose: bool = True) -> Dict:
    """Full gate: AST rules + the jaxpr matrix (+ donation/sentinel unless
    ``quick``). Returns the ANALYSIS.json payload."""
    donation = (not quick) if donation is None else donation
    sentinel = (not quick) if sentinel is None else sentinel
    t0 = time.time()
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))   # .../src/repro
    from repro.analysis.astlint import lint_path
    ast_viols = lint_path(src_root)
    matrix: Dict[str, Dict] = {}
    n_viols = len(ast_viols)
    for alg_name, codec in _cells(only):
        cell = f"{alg_name}x{codec}"
        tc = time.time()
        try:
            rep = analyze_cell(alg_name, codec, donation=donation)
        except Exception as e:   # an unanalyzable cell is itself a finding
            rep = {"violations": [{
                "rule": "analyzer-error", "where": cell,
                "detail": f"{type(e).__name__}: {e}"}]}
        rep["seconds"] = round(time.time() - tc, 2)
        matrix[cell] = rep
        n_viols += len(rep["violations"])
        if verbose:
            status = ("ok" if not rep["violations"]
                      else f"{len(rep['violations'])} VIOLATIONS")
            print(f"# {cell}: {status} ({rep['seconds']}s)", flush=True)
    rs_rep: Dict = {}
    if only is None or only in "shard_local_rs":
        tr = time.time()
        try:
            rs_rep = rs_transport_audit()
        except Exception as e:
            rs_rep = {"violations": [{
                "rule": "analyzer-error", "where": "shard_local_rs",
                "detail": f"{type(e).__name__}: {e}"}]}
        rs_rep["seconds"] = round(time.time() - tr, 2)
        n_viols += len(rs_rep["violations"])
        if verbose:
            status = ("ok" if not rs_rep["violations"]
                      else f"{len(rs_rep['violations'])} VIOLATIONS")
            print(f"# rs_transport: {status} ({rs_rep['seconds']}s)",
                  flush=True)
    sentinels: Dict[str, Dict] = {}
    if sentinel:
        for alg_name, codec in _cells(only):
            if codec != "lattice":   # one scanned run per algorithm
                continue
            ts = time.time()
            try:
                rep = sentinel_run(alg_name)
            except Exception as e:
                rep = {"violations": [{
                    "rule": "analyzer-error", "where": alg_name,
                    "detail": f"{type(e).__name__}: {e}"}]}
            rep["seconds"] = round(time.time() - ts, 2)
            sentinels[alg_name] = rep
            n_viols += len(rep["violations"])
            if verbose:
                status = ("ok" if not rep["violations"]
                          else f"{len(rep['violations'])} VIOLATIONS")
                print(f"# sentinel {alg_name}: {status} "
                      f"({rep['seconds']}s)", flush=True)
    return {
        "schema": "analysis.v1",
        "quick": bool(quick),
        "violations_total": n_viols,
        "ast": {"root": src_root,
                "violations": [v.as_dict() for v in ast_viols]},
        "matrix": matrix,
        "rs_transport": rs_rep,
        "sentinel": sentinels,
        "seconds": round(time.time() - t0, 2),
    }


def default_json_path() -> str:
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))   # repo root
    return os.path.join(root, "ANALYSIS.json")


def _arg_value(argv: List[str], flag: str) -> Optional[str]:
    if flag in argv:
        i = argv.index(flag)
        if i + 1 < len(argv):
            return argv[i + 1]
    return None


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    report = run_lint(quick="--quick" in argv,
                      only=_arg_value(argv, "--only"))
    path = _arg_value(argv, "--json") or default_json_path()
    if path != "-":
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {path}")
    n = report["violations_total"]
    print(f"# repro.analysis.lint: {n} violation(s) in "
          f"{report['seconds']}s")
    if n:
        for v in report["ast"]["violations"]:
            print(f"AST  {v['rule']} {v['where']}: {v['detail']}")
        for cell, rep in (list(report["matrix"].items())
                          + [("rs_transport", report["rs_transport"])]
                          + list(report["sentinel"].items())):
            for v in rep.get("violations", []):
                print(f"JXPR {v['rule']} {v['where']}: {v['detail']}")
    return min(n, 125)


if __name__ == "__main__":
    sys.exit(main())
