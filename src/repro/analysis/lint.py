"""``python -m repro.analysis.lint`` — the repo's static-analysis gate.

Runs every half of :mod:`repro.analysis` and writes a machine-readable,
**deterministic** ``ANALYSIS.json`` (schema ``analysis.v2`` — byte-
identical across runs; wall-clock timings go to ``bench_out/``, not the
committed report):

* **jaxpr matrix** — every registry algorithm × {lattice, lattice_packed,
  topk_ef} uplink codec is built at a tiny config, its round and scanned
  chunk traced through :meth:`RoundEngine.traced_round` / ``traced_chunk``,
  and checked for host callbacks, wide dtypes, key discipline, the
  rotation op-budget, the donation contract of the compiled chunk — plus
  the PR 10 dataflow analyzers on the round trace: the wire-truth audit
  (:mod:`repro.analysis.wire`), γ-overflow interval analysis
  (:mod:`repro.analysis.intervals`) and SPMD divergence detection
  (:mod:`repro.analysis.divergence`). A scanned ``simulate()`` run per
  algorithm feeds the recompile sentinel.
* **exchange matrix** — every codec × transport pair of the shard-local
  exchange is traced on an abstract (4, 2) data×model mesh and audited
  against the transport's declared :class:`~repro.compression.transports.
  WireBudget`: wire-truth (every gathered payload marked + container-
  exact), per-collective byte caps, divergence escapes, and the
  reduce-scatter γ_rs wrap proof.
* **AST rules** — :func:`repro.analysis.astlint.lint_path` over
  ``src/repro/``.
* **rs transport byte budget** — the historical ``rs_transport_audit``
  cell, now budgeted by ``ReduceScatterSum.wire_budget`` instead of
  hand-pinned caps.

Exit status is the number of violations (0 = clean). Flags::

    --json PATH      where to write the report (default: repo-root
                     ANALYSIS.json; "-" to skip writing)
    --quick          skip the donation compiles and sentinel runs (the two
                     expensive passes) — trace-level + AST checks only
    --only SUBSTR    run only cells whose name contains SUBSTR (e.g.
                     --only quaflxlattice, --only exchange:). Unknown
                     selectors are a loud error listing every cell.
    --list           print every cell name the gate would run, then exit

Registering a new analyzer = writing a function returning
``List[Violation]`` and appending it in :func:`analyze_cell` /
:func:`analyze_exchange_cell` (jaxpr-level) or
:func:`repro.analysis.astlint.lint_source` (source-level); the README
"Static analysis" section walks through it.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Dict, List, Optional

# algorithm × codec matrix ---------------------------------------------------

MATRIX_CODECS = ("lattice", "lattice_packed", "topk_ef")

# codec × transport exchange matrix (abstract-mesh shard_map traces)
MATRIX_TRANSPORTS = ("shard_local", "code_allgather", "reduce_scatter")
_EXCHANGE_CODECS = ("lattice:bits=8", "lattice_packed:bits=4", "topk_ef")

# per-algorithm construction kwargs at the tiny lint config
_ALG_KWARGS = {"fedbuff_device": {"buffer_size": 2}}

# sparse EF uplink composes with every algorithm; the fused lattice
# downlink families also run the downlink direction
_DOWNLINK_OK = ("lattice", "lattice_packed")


def _cells(only: Optional[str] = None):
    from repro.fed.registry import registered_algorithms
    algs = [a for a in registered_algorithms() if a != "fedbuff"]
    for alg in algs:
        codecs = MATRIX_CODECS
        if alg == "quafl":
            # heterogeneous per-client widths: the batched exchange with a
            # levels row — the PR 9 side channel the wire audit must see
            codecs = codecs + ("lattice_grouped",)
        for codec in codecs:
            cell = f"{alg}x{codec}"
            if only and only not in cell:
                continue
            yield alg, codec


def _exchange_cell_name(codec: str, transport: str) -> str:
    return f"exchange:{codec.split(':')[0]}x{transport}"


def _exchange_cells(only: Optional[str] = None):
    for codec in _EXCHANGE_CODECS:
        for transport in MATRIX_TRANSPORTS:
            if only and only not in _exchange_cell_name(codec, transport):
                continue
            yield codec, transport


def list_cells() -> List[str]:
    """Every cell name the full gate runs (the ``--list`` surface)."""
    names = [f"{a}x{c}" for a, c in _cells()]
    names += [_exchange_cell_name(c, t) for c, t in _exchange_cells()]
    names += ["rs_transport"]
    names += [f"sentinel:{a}" for a, c in _cells() if c == "lattice"]
    return names


def _build_cell(alg_name: str, codec: str):
    """Build (alg, params0, data, key) at the tiny lint config."""
    import jax
    from repro.configs.base import FedConfig
    from repro.fed.registry import make_algorithm
    kw = dict(_ALG_KWARGS.get(alg_name, {}))
    if codec == "lattice_grouped":
        # dict specs resolve against the clock's straggler mask into ONE
        # GroupedLatticeCodec (mixed 8/4-bit member widths)
        kw["uplink"] = {"fast": "lattice", "slow": "lattice:bits=4"}
        codec, down = "", ""
    else:
        down = codec if codec.split(":")[0] in _DOWNLINK_OK else ""
    if alg_name == "spmd":
        from functools import partial
        from repro.configs import get_reduced
        from repro.data.synthetic import federated_token_task
        from repro.models.model import init_lm, lm_loss
        cfg = get_reduced("llama3.2-1b")
        fed = FedConfig(n_clients=1, s=1, local_steps=1, lr=0.02,
                        codec_up=codec, codec_down=down)
        params0, _ = init_lm(cfg, jax.random.PRNGKey(0))
        data, batch_fn = federated_token_task(0, 1, 32, 2, 16,
                                              cfg.vocab_size)
        alg = make_algorithm("spmd", fed, loss_fn=partial(lm_loss, cfg),
                             template=params0, batch_fn=batch_fn, cfg=cfg,
                             batch=2, seq=16, **kw)
        return alg, data, params0, jax.random.PRNGKey(1)
    from repro.data import make_federated_classification
    from repro.data.synthetic import client_batch
    from repro.models.mlp import init_mlp_classifier, mlp_loss
    d, hidden, classes = 16, 16, 4
    fed = FedConfig(n_clients=4, s=2, local_steps=1, lr=0.2, bits=8,
                    codec_up=codec, codec_down=down)
    part, _ = make_federated_classification(0, fed.n_clients, d=d,
                                            n_classes=classes)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(0), d, hidden,
                                     classes)
    alg = make_algorithm(alg_name, fed, loss_fn=mlp_loss, template=params0,
                         batch_fn=lambda dd, k: client_batch(k, dd, d),
                         **kw)
    return alg, part, params0, jax.random.PRNGKey(1)


def _traceable(alg):
    """The (algorithm, init-state) pair the engine hooks trace. An
    algorithm with custom ``scan_rounds`` host control (adaptive bit-width)
    is analyzed through its current-bits inner algorithm."""
    inner_of = getattr(alg, "_alg", None)
    if callable(getattr(alg, "scan_rounds", None)) and callable(inner_of):
        return inner_of(int(alg.fed.bits))
    return alg


def _codec_pipe(codec):
    """An ``ExchangePipeline`` with the codec's own γ derivation (bits,
    block, safety) — the interval analyzers trace through it."""
    from repro.compression.pipeline import ExchangePipeline
    return ExchangePipeline(bits=int(codec.bits), block=codec.block,
                            backend="jnp", safety=float(codec.safety))


def flow_checks(closed, target, d: int, where: str) -> List:
    """The PR 10 dataflow analyzers over one traced round program:
    wire-truth audit + γ-overflow interval proofs + divergence escapes.
    ``target`` is the algorithm whose round ``closed`` traces — its OWN
    resolved codecs are the declarations to audit against (algorithms pick
    per-direction defaults, e.g. an identity downlink broadcast)."""
    from repro.analysis.divergence import check_divergence
    from repro.analysis.intervals import (check_encode_intervals,
                                          check_gamma_window)
    from repro.analysis.wire import check_wire_truth
    from repro.compression.codecs import resolve_codec

    fed = target.fed
    up = getattr(target, "codec_up", None)
    dn = getattr(target, "codec_down", None)
    up = up if up is not None else resolve_codec(None, fed, direction="up")
    dn = dn if dn is not None else resolve_codec(None, fed,
                                                 direction="down")
    decl_up = (up.wire_declaration(d)
               if hasattr(up, "wire_declaration") else None)
    decl_dn = (dn.wire_declaration(d)
               if hasattr(dn, "wire_declaration") else None)
    viols = check_wire_truth(closed, where=where, decl_up=decl_up,
                             decl_down=decl_dn, codec_up=up, codec_down=dn,
                             d=d)
    viols += check_divergence(closed, where)
    from repro.compression.pipeline import LatticeWire
    for direction, codec in (("up", up), ("down", dn)):
        if getattr(codec, "family", "") != "lattice":
            continue
        pipe = _codec_pipe(codec)
        # a grouped codec runs one batched exchange with per-message
        # moduli; each member's wrap proof is the uniform-width proof at
        # ITS bit-width (the interval domain cannot couple the levels row
        # to the matching γ rows, so prove member-by-member)
        member_bits = sorted(set(getattr(codec, "bits_per_client",
                                         (int(codec.bits),))))
        for b in member_bits:
            # unpacked uniform wire: packing is a relayout of in-range
            # codes, and γ/wrap are functions of the bit-width alone
            wire = LatticeWire(bits=int(b), pack=1)
            tag = (f"{where}/{direction}" if len(member_bits) == 1
                   else f"{where}/{direction}@bits{b}")
            viols += check_encode_intervals(pipe, wire, d, (1 << int(b),),
                                            tag)
            viols += check_gamma_window(pipe, wire, d, tag)
    return viols


def analyze_cell(alg_name: str, codec: str, *, donation: bool = True,
                 chunk: int = 2) -> Dict:
    """All jaxpr-level checks for one (algorithm, codec) cell."""
    import jax
    from repro.analysis.donation import audit_engine_chunk, donation_report
    from repro.analysis.jaxpr import analyze_jaxpr
    from repro.analysis.opbudget import (measure_round_counters,
                                         rotation_budget)
    from repro.fed.engine import RoundEngine
    cell = f"{alg_name}x{codec}"
    alg, data, params0, key = _build_cell(alg_name, codec)
    target = _traceable(alg)
    state = target.init(params0)
    eng = RoundEngine(target)

    viols = []
    closed_r = eng.traced_round(state, data, key)
    vs, ops = analyze_jaxpr(closed_r, f"{cell}/round")
    viols += vs
    model_dim = sum(int(x.size)
                    for x in jax.tree_util.tree_leaves(params0))
    viols += flow_checks(closed_r, target, model_dim, f"{cell}/round")
    closed_c = eng.traced_chunk(state, data, key, chunk)
    vs, ops_chunk = analyze_jaxpr(closed_c, f"{cell}/chunk{chunk}")
    viols += vs

    report: Dict = {"ops_round": ops, "ops_chunk": ops_chunk}
    # measure ONCE: a second trace of the same (self, avals) signature hits
    # the pjit trace cache and the python body (where the counters live)
    # never re-runs
    measured = measure_round_counters(target, state, data, key)
    if measured is not None:
        report["rotation_counters"] = dict(measured.counters)
        # the s+1/s+1 budget binds algorithms that route through the fused
        # rotated exchange; an inherited-but-unused pipeline (scaffold runs
        # stateless codec encodes instead) legitimately counts zero
        if any(measured.counters.values()):
            viols += measured.expect(f"{cell}/round",
                                     rotation_budget(int(target.fed.s)))
    if donation:
        viols += audit_engine_chunk(eng, state, data, key, chunk,
                                    f"{cell}/chunk{chunk}")
        report["donation"] = donation_report(eng, state, data, key, chunk)
    report["violations"] = [v.as_dict() for v in viols]
    return report


def _trace_exchange(codec_up_spec: str, codec_dn_spec: str,
                    transport_name: str, d: int, n: int,
                    model_sharded: bool = True):
    """Trace the shard-local exchange for one codec/transport pair on an
    abstract (n, 2) data×model mesh; returns (closed, up, dn, transport).

    ``model_sharded`` mirrors the pod layout the launcher builds (leaves
    sharded over the model axes — the exchange folds the model-rank into
    the rotation key, so each rank must own its block). The historical
    ``rs_transport_audit`` traces the replicated layout instead (its byte
    pins are at the full leaf dimension)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh, PartitionSpec as P
    from repro.compression.codecs import resolve_codec
    from repro.compression.transports import make_transport
    from repro.configs.base import FedConfig
    from repro.core.exchange_local import make_shardlocal_exchange

    mesh = AbstractMesh((("data", n), ("model", 2)))
    fed = FedConfig(n_clients=n, s=n, bits=8, codec_up=codec_up_spec,
                    codec_down=codec_dn_spec)
    up = resolve_codec(None, fed, direction="up")
    dn = resolve_codec(None, fed, direction="down")
    transport = make_transport(transport_name)
    srv_ps = {"w": P("model")} if model_sharded else {"w": P()}
    cl_ps = {"w": P("data", "model")} if model_sharded else {"w": P("data")}
    ex = make_shardlocal_exchange(
        up, dn, mesh, srv_ps, cl_ps, "data", n, transport=transport)
    srv = {"w": jax.ShapeDtypeStruct((d,), jnp.float32)}
    cl = {"w": jax.ShapeDtypeStruct((n, d), jnp.float32)}
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    closed = jax.make_jaxpr(ex)(srv, cl, cl, key)
    return closed, up, dn, transport


def analyze_exchange_cell(codec: str, transport_name: str,
                          d: int = 1 << 16, n: int = 4) -> Dict:
    """Wire-truth + byte-budget + divergence (+ γ_rs wrap proof) for one
    codec × transport pair of the shard-local exchange."""
    from repro.analysis.divergence import check_divergence
    from repro.analysis.intervals import check_rs_gamma_window
    from repro.analysis.jaxpr import op_report
    from repro.analysis.wire import check_wire_truth

    cell = _exchange_cell_name(codec, transport_name)
    dn_spec = codec if codec.split(":")[0] in _DOWNLINK_OK else ""
    closed, up, dn, transport = _trace_exchange(codec, dn_spec,
                                                transport_name, d, n)
    budget = transport.wire_budget(up, dn, d, n)
    d_leaf = d + (-d) % 1024   # the exchange pads leaves to 1024 multiples
    decl_up = (up.wire_declaration(d_leaf)
               if hasattr(up, "wire_declaration") else None)
    decl_dn = (dn.wire_declaration(d_leaf)
               if hasattr(dn, "wire_declaration") else None)
    viols = check_wire_truth(closed, where=cell, decl_up=decl_up,
                             decl_down=decl_dn, codec_up=up, codec_down=dn,
                             d=d_leaf, budget=budget)
    viols += check_divergence(closed, cell)
    if (transport_name == "reduce_scatter"
            and getattr(dn, "family", "") == "lattice"):
        viols += check_rs_gamma_window(_codec_pipe(dn), dn.wire(), d_leaf,
                                       n, cell)
    return {"ops": op_report(closed),
            "violations": [v.as_dict() for v in viols]}


def sentinel_run(alg_name: str, *, rounds: int = 4, chunk: int = 2,
                 codec: str = "lattice") -> Dict:
    """Prove one-compile-per-(algorithm, chunk length) on a real scanned
    ``simulate()`` run: record the chunk fingerprint before the run, run,
    re-record, then interrogate every engine jit cache."""
    import jax
    from repro.analysis.sentinel import RecompileSentinel
    from repro.fed.simulate import simulate
    alg, data, params0, key = _build_cell(alg_name, codec)
    target = _traceable(alg)
    sentinel = RecompileSentinel()
    tag = f"{alg_name}x{codec}"

    from repro.fed.engine import RoundEngine
    pre = RoundEngine(target).traced_chunk(target.init(params0), data,
                                           jax.random.PRNGKey(1), chunk)
    sentinel.record((tag, chunk), pre)
    simulate(alg, params0, data, jax.random.PRNGKey(2), rounds=rounds,
             eval_every=0, scan_chunk=chunk)
    engines = [("", e) for e in [getattr(alg, "_round_engine", None)]
               if e is not None]
    # an adaptive wrapper compiles one program per visited bit-width: same
    # one-compile contract, separate tag per width (the width the pre-run
    # fingerprint pinned keeps the bare tag)
    engines += [("" if b == int(alg.fed.bits) else f"@bits{b}", e)
                for b, e in getattr(alg, "_engines", {}).items()]
    compiles = {}
    for subtag, eng in engines:
        sentinel.check_engine((tag + subtag, chunk), eng)
        if not callable(getattr(eng.alg, "device_round", None)):
            # engine over a custom-scan_rounds wrapper (adaptive): its
            # chunk cache is never populated — the inner engines above
            # carry the compiled programs — and it has nothing to trace
            continue
        post = eng.traced_chunk(eng.alg.init(params0), data,
                                jax.random.PRNGKey(1), chunk)
        sentinel.record((tag + subtag, chunk), post)
        for length, fn in eng._chunk_fns.items():
            try:
                compiles[f"chunk{length}{subtag}"] = fn._cache_size()
            except AttributeError:
                pass
    return {"violations": [v.as_dict() for v in sentinel.report()],
            "compiles": compiles}


def rs_transport_audit(d: int = 1 << 16, n: int = 4) -> Dict:
    """Trace the fused ``shard_local_rs`` exchange on an ABSTRACT (4, 2)
    data×model mesh (no devices needed — ``AbstractMesh`` + ``make_jaxpr``
    trace the same shard_map program a pod runs) and budget its per-device
    collective payload against the transport's own
    :meth:`~repro.compression.transports.ReduceScatterSum.wire_budget`
    declaration (PR 9 pinned these caps by hand; the declaration now IS
    the budget):

      * the redistribution ``all_gather`` must move integer codes plus
        scalar f32 γ rows only — a regression back to the fp32 re-gather
        (``all_gather_fbytes`` jumping from a handful of scalars to d·4)
        fails the gate,
      * no full-size fp32 ``psum`` may sneak back in either (the
        exact-psum fallback silently replacing the coded path on a
        shardable chunk would show up as ``psum_fbytes`` ≈ d·4).

    The reducing phase (``psum_scatter`` of the snapped fp32 chunks) is
    the one collective that legitimately moves d·4 float bytes.
    """
    from repro.analysis.jaxpr import analyze_jaxpr
    from repro.analysis.opbudget import check_collective_bytes

    closed, up, dn, transport = _trace_exchange(
        "lattice_packed:bits=4", "lattice_packed:bits=4", "reduce_scatter",
        d, n, model_sharded=False)
    where = "shard_local_rs/exchange@mesh(4,2)"
    viols, ops = analyze_jaxpr(closed, where)
    viols += check_collective_bytes(closed, where,
                                    transport.wire_budget(up, dn, d, n).caps)
    return {"ops": ops, "violations": [v.as_dict() for v in viols]}


def run_lint(*, quick: bool = False, only: Optional[str] = None,
             donation: Optional[bool] = None,
             sentinel: Optional[bool] = None, verbose: bool = True,
             timings: Optional[Dict[str, float]] = None) -> Dict:
    """Full gate: AST rules + the jaxpr matrix + the exchange matrix
    (+ donation/sentinel unless ``quick``). Returns the ANALYSIS.json
    payload — deterministic by construction: wall-clock seconds go to the
    optional ``timings`` dict (cell name → seconds), never the report.

    An ``only`` selector that matches no cell raises ``SystemExit`` with
    the full cell list — a typo must not silently run an empty gate."""
    donation = (not quick) if donation is None else donation
    sentinel = (not quick) if sentinel is None else sentinel
    timings = {} if timings is None else timings
    t0 = time.time()
    if only is not None and not any(only in name for name in list_cells()):
        raise SystemExit(
            f"--only {only!r} matches no analysis cell; known cells:\n  "
            + "\n  ".join(list_cells()))
    src_root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))   # .../src/repro
    from repro.analysis.astlint import lint_path
    ast_viols = lint_path(src_root)
    n_viols = len(ast_viols)

    def _run(section: Dict, name: str, label: str, fn) -> None:
        nonlocal n_viols
        tc = time.time()
        try:
            rep = fn()
        except Exception as e:   # an unanalyzable cell is itself a finding
            rep = {"violations": [{
                "rule": "analyzer-error", "where": name,
                "detail": f"{type(e).__name__}: {e}"}]}
        timings[label] = round(time.time() - tc, 2)
        section[name] = rep
        n_viols += len(rep["violations"])
        if verbose:
            status = ("ok" if not rep["violations"]
                      else f"{len(rep['violations'])} VIOLATIONS")
            print(f"# {label}: {status} ({timings[label]}s)", flush=True)

    matrix: Dict[str, Dict] = {}
    for alg_name, codec in _cells(only):
        cell = f"{alg_name}x{codec}"
        _run(matrix, cell, cell,
             lambda a=alg_name, c=codec: analyze_cell(a, c,
                                                      donation=donation))
    exchange: Dict[str, Dict] = {}
    for codec, transport in _exchange_cells(only):
        cell = _exchange_cell_name(codec, transport)
        _run(exchange, cell, cell,
             lambda c=codec, t=transport: analyze_exchange_cell(c, t))
    rs_section: Dict[str, Dict] = {}
    if only is None or only in "rs_transport":
        _run(rs_section, "rs_transport", "rs_transport", rs_transport_audit)
    sentinels: Dict[str, Dict] = {}
    if sentinel:
        for alg_name, codec in _cells(only):
            if codec != "lattice":   # one scanned run per algorithm
                continue
            _run(sentinels, alg_name, f"sentinel:{alg_name}",
                 lambda a=alg_name: sentinel_run(a))
    timings["total"] = round(time.time() - t0, 2)
    return {
        "schema": "analysis.v2",
        "quick": bool(quick),
        "violations_total": n_viols,
        "ast": {"root": src_root,
                "violations": [v.as_dict() for v in ast_viols]},
        "matrix": matrix,
        "exchange": exchange,
        "rs_transport": rs_section.get("rs_transport", {}),
        "sentinel": sentinels,
    }


def default_json_path() -> str:
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))   # repo root
    return os.path.join(root, "ANALYSIS.json")


def _arg_value(argv: List[str], flag: str) -> Optional[str]:
    if flag in argv:
        i = argv.index(flag)
        if i + 1 < len(argv):
            return argv[i + 1]
    return None


def _write_timings(timings: Dict[str, float]) -> str:
    """Raw wall-clock per cell — gitignored ``bench_out/``, never the
    committed ANALYSIS.json (which must be byte-stable across runs)."""
    root = os.path.dirname(default_json_path())
    out_dir = os.path.join(root, "bench_out")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "analysis_timings.json")
    with open(path, "w") as f:
        json.dump(timings, f, indent=2, sort_keys=True)
    return path


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for name in list_cells():
            print(name)
        return 0
    timings: Dict[str, float] = {}
    report = run_lint(quick="--quick" in argv,
                      only=_arg_value(argv, "--only"), timings=timings)
    path = _arg_value(argv, "--json") or default_json_path()
    if path != "-":
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {path}")
    print(f"# timings: {_write_timings(timings)}")
    n = report["violations_total"]
    print(f"# repro.analysis.lint: {n} violation(s) in "
          f"{timings.get('total', 0.0)}s")
    if n:
        for v in report["ast"]["violations"]:
            print(f"AST  {v['rule']} {v['where']}: {v['detail']}")
        for cell, rep in (list(report["matrix"].items())
                          + list(report["exchange"].items())
                          + [("rs_transport", report["rs_transport"])]
                          + list(report["sentinel"].items())):
            for v in rep.get("violations", []):
                print(f"JXPR {v['rule']} {v['where']}: {v['detail']}")
    return min(n, 125)


if __name__ == "__main__":
    sys.exit(main())
