"""Wire-truth audit: traced message payloads vs. declared wire formats.

The repo's reproduction claim is its ``bits_up``/``bits_down`` accounting,
so this analyzer makes the accounting *checkable*: every codec exports a
machine-readable :class:`repro.compression.codecs.WireDecl` and every
message-creation site carries a ``wire_mark`` (see
``repro.analysis.provenance``). A taint dataflow over the traced round
(:class:`WireTaintDomain` on the flow engine) then:

* locates every mark and cross-checks the traced value against the
  declared part — container bit-width, element count, int/float kind. An
  fp32 value marked as a 4-bit-charged payload is a violation here, not a
  silently wrong BENCH row;
* rejects traced message parts the declaration does not charge (an
  uncharged side-channel row, e.g. a levels row on a codec that never
  declared one);
* checks declaration self-consistency (``decl.message_bits`` must equal
  the codec's ``message_bits(d)``; a payload may not charge sub-16-bit
  coords while declaring a >= 32-bit container);
* on distributed traces, meters every collective against the transport's
  :class:`repro.compression.transports.WireBudget` and requires gathered
  payloads to be tainted by a wire mark — a model-derived fp32 array
  entering an all_gather (or a psum on a transport that declares
  ``float_reduce_ok=False``) is flagged as a wire leak.
"""

from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.analysis.flow import FlowContext, JoinAllDomain, analyze_flow
from repro.analysis.jaxpr import Violation
from repro.analysis.provenance import MARK_PRIM_NAME

_GATHER_OPS = {"all_gather"}
_REDUCE_OPS = {"psum", "psum_scatter", "reduce_scatter", "all_reduce"}
_COLLECTIVES = _GATHER_OPS | _REDUCE_OPS

# operands at or below this footprint are scalar side traffic (hints,
# counters), never a model payload
_SCALAR_BYTES = 256

_TOP = frozenset({("any",)})


def _dtype_bits(dtype) -> int:
    return np.dtype(dtype).itemsize * 8


def _is_float(dtype) -> bool:
    return np.issubdtype(np.dtype(dtype), np.floating)


class WireTaintDomain(JoinAllDomain):
    """May-taint: which wire marks (if any) a value derives from."""

    def top(self, aval):
        return _TOP

    def bottom(self):
        return frozenset()

    def join(self, a, b):
        return a | b

    def transfer(self, eqn, ins):
        if eqn.primitive.name == MARK_PRIM_NAME:
            p = eqn.params
            tag = ("mark", p.get("channel", ""), p.get("part", ""),
                   p.get("codec", ""))
            return [ins[0] | {tag}]
        return super().transfer(eqn, ins)

    def on_eqn(self, eqn, ins, outs, ctx: FlowContext):
        name = eqn.primitive.name
        if name == MARK_PRIM_NAME:
            ctx.facts.append(
                ("mark", dict(eqn.params), eqn.invars[0].aval, ctx.where))
        elif name in _COLLECTIVES:
            ops = [(v.aval, t) for v, t in zip(eqn.invars, ins)]
            ctx.facts.append(("collective", name, ops, ctx.where))


def collect_wire_facts(closed):
    """(marks, collectives) found by the taint flow over ``closed``.

    marks: list of (params, aval, path); collectives: list of
    (prim_name, [(aval, taint), ...], path).
    """
    res = analyze_flow(closed, WireTaintDomain())
    marks, colls = [], []
    for fact in res.context.facts:
        if fact[0] == "mark":
            marks.append(fact[1:])
        else:
            colls.append(fact[1:])
    return marks, colls


def _mark_elems(params: Dict, aval) -> int:
    """Per-message wire elements at a mark site (leading axis = message
    batch when ``batched``)."""
    size = int(np.prod(aval.shape)) if aval.shape else 1
    if params.get("batched") and aval.shape:
        lead = max(int(aval.shape[0]), 1)
        return size // lead
    return size


def _resolve_decl(params: Dict, decl_up, decl_down, by_name: Dict):
    channel = params.get("channel", "")
    if channel == "up":
        return decl_up
    if channel == "down":
        return decl_down
    return by_name.get(params.get("codec", ""))


def _part_at_mark_dim(codec, part, params: Dict):
    """The declared part rebuilt at the mark's own encode dimension.

    Marks record the leaf/model dimension ``d`` they encoded (see
    ``wire_mark``); mesh exchanges encode per-leaf chunks, so the exact
    element count to audit against is the codec's declaration at THAT
    granularity, not the caller's flat-model one. The container must not
    drift with d — if it does, audit against the caller's declaration."""
    d_mark = int(params.get("d", 0) or 0)
    if not d_mark or codec is None \
            or not hasattr(codec, "wire_declaration"):
        return part
    try:
        rp = codec.wire_declaration(d_mark).part(part.part)
    except (TypeError, ValueError):
        return part
    if rp is None or rp.container_bits != part.container_bits:
        return part
    return rp


def _leaf_elems_ok(codec, part, got_elems: int) -> bool:
    """Mesh exchanges encode PER-LEAF chunks, so a mark's element count
    legitimately differs from the flat-model declaration; accept it iff
    the codec's own declaration at the mark's granularity produces exactly
    this count with the same container (sizes a mesh leaf could not have —
    unpadded, or wrong pack — still fail)."""
    if codec is None or part.part != "codes":
        return False
    pack = max(int(getattr(codec, "pack", 1) or 1), 1)
    try:
        redecl = codec.wire_declaration(got_elems * pack)
    except (AttributeError, TypeError, ValueError):
        return False
    rp = redecl.part("codes")
    return (rp is not None and rp.elems == got_elems
            and rp.container_bits == part.container_bits)


def check_wire_truth(closed, *, where: str, decl_up=None, decl_down=None,
                     codec_up=None, codec_down=None, d: int = None,
                     budget=None) -> List[Violation]:
    """Audit one traced program against its wire declarations.

    ``decl_up``/``decl_down`` are the per-direction :class:`WireDecl`s
    (built by the caller at the model dimension ``d``); ``codec_up``/
    ``codec_down`` additionally enable the declaration-consistency checks.
    ``budget`` (a transport :class:`WireBudget`) arms the collective
    checks for distributed traces.
    """
    out: List[Violation] = []
    by_name = {}
    for decl in (decl_up, decl_down):
        if decl is not None:
            by_name.setdefault(decl.codec, decl)

    # declaration self-consistency (trace-independent)
    for decl, codec in ((decl_up, codec_up), (decl_down, codec_down)):
        if decl is None:
            continue
        if codec is not None and d is not None:
            declared, charged = decl.message_bits, codec.message_bits(d)
            if declared != charged:
                out.append(Violation(
                    "wire_truth", where,
                    f"declaration drift for {decl.codec!r}: wire parts sum "
                    f"to {declared} bits but message_bits({d}) charges "
                    f"{charged}"))
        for p in decl.parts:
            if p.payload and p.elems and p.container_bits >= 32 \
                    and p.charged_bits / p.elems < 16:
                out.append(Violation(
                    "wire_truth", where,
                    f"{decl.codec!r} part {p.part!r} declares a "
                    f"{p.container_bits}-bit container but charges only "
                    f"{p.charged_bits / p.elems:.1f} bits/coord"))

    marks, colls = collect_wire_facts(closed)
    codec_of = {"up": codec_up, "down": codec_down}

    for params, aval, path in marks:
        decl = _resolve_decl(params, decl_up, decl_down, by_name)
        codec = codec_of.get(params.get("channel", ""))
        if codec is None and decl is not None:
            for cand in (codec_up, codec_down):
                if cand is not None and getattr(cand, "name", "") == decl.codec:
                    codec = cand
                    break
        label = (f"{params.get('channel')}/{params.get('part')}"
                 f" ({params.get('codec')})")
        if decl is None:
            out.append(Violation(
                "wire_truth", where,
                f"wire mark {label} at {path} matches no declaration — "
                f"uncharged message traffic"))
            continue
        part = decl.part(params.get("part", ""))
        if part is None:
            out.append(Violation(
                "wire_truth", where,
                f"{decl.codec!r} ships an undeclared part "
                f"{params.get('part')!r} at {path} — uncharged side-"
                f"channel row"))
            continue
        got_bits = _dtype_bits(aval.dtype)
        if got_bits != part.container_bits:
            out.append(Violation(
                "wire_truth", where,
                f"{decl.codec!r} part {part.part!r} traces a {got_bits}-"
                f"bit container at {path}; declaration says "
                f"{part.container_bits} (message charges "
                f"{part.charged_bits} bits)"))
        got_kind = "float" if _is_float(aval.dtype) else "int"
        if got_kind != part.kind:
            out.append(Violation(
                "wire_truth", where,
                f"{decl.codec!r} part {part.part!r} traces {got_kind} "
                f"({np.dtype(aval.dtype).name}) at {path}; declaration "
                f"says {part.kind} — fp32 reaching the wire"
                if got_kind == "float" else
                f"{decl.codec!r} part {part.part!r} traces {got_kind} at "
                f"{path}; declaration says {part.kind}"))
        got_elems = _mark_elems(params, aval)
        expect = _part_at_mark_dim(codec, part, params)
        if expect.elems and got_elems != expect.elems \
                and not _leaf_elems_ok(codec, part, got_elems):
            out.append(Violation(
                "wire_truth", where,
                f"{decl.codec!r} part {part.part!r} traces {got_elems} "
                f"elements/message at {path}; declaration says "
                f"{expect.elems}"))

    if budget is not None:
        from repro.analysis.opbudget import check_collective_bytes
        out.extend(check_collective_bytes(closed, where, budget.caps))
        for prim, ops, path in colls:
            for aval, taint in ops:
                nbytes = (int(np.prod(aval.shape)) if aval.shape else 1) \
                    * np.dtype(aval.dtype).itemsize
                if nbytes <= _SCALAR_BYTES:
                    continue
                marked = any(t and t[0] == "mark" for t in taint)
                if prim in _GATHER_OPS and not marked:
                    out.append(Violation(
                        "wire_truth", where,
                        f"{prim} at {path} gathers a {nbytes}-byte "
                        f"{np.dtype(aval.dtype).name} payload with no "
                        f"wire mark — undeclared wire traffic"))
                elif (prim in _REDUCE_OPS and _is_float(aval.dtype)
                        and not budget.float_reduce_ok and not marked):
                    out.append(Violation(
                        "wire_truth", where,
                        f"{prim} at {path} reduces a {nbytes}-byte fp32 "
                        f"payload on a transport that declares no float "
                        f"reduction — wire leak"))
    return out
