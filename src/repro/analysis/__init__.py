"""Static analysis for traced federated rounds — jaxpr + AST invariants.

Three layers (see ``README.md`` § Static analysis):

* jaxpr analyzers (:mod:`repro.analysis.jaxpr`, ``opbudget``, ``donation``,
  ``sentinel``) — walk the closed jaxpr / lowered HLO of every registry
  algorithm's round, via ``RoundEngine.traced_round()`` / ``traced_chunk()``.
* dataflow analyzers on the worklist engine (:mod:`repro.analysis.flow`) —
  the wire-truth taint audit (:mod:`repro.analysis.wire`), γ-overflow
  interval analysis (:mod:`repro.analysis.intervals`) and SPMD divergence
  detection (:mod:`repro.analysis.divergence`).
* AST repo rules (:mod:`repro.analysis.astlint`) — source-level checks over
  ``src/repro/``.

``python -m repro.analysis.lint`` runs everything over the full
algorithm × codec (and codec × transport) matrix and writes
``ANALYSIS.json``. Keep this package __init__ import-light:
``compression.pipeline`` imports ``opbudget`` at instance-construction
time, so pulling registries in here would be a cycle.
"""
from repro.analysis.divergence import (DivergenceDomain,  # noqa: F401
                                       check_divergence)
from repro.analysis.flow import (FlowContext, FlowDomain,  # noqa: F401
                                 FlowResult, JoinAllDomain, analyze_flow)
from repro.analysis.intervals import (IntervalDomain,  # noqa: F401
                                      check_encode_intervals,
                                      check_gamma_window,
                                      check_rs_gamma_window, interval_of)
from repro.analysis.jaxpr import (Violation, analyze_jaxpr,  # noqa: F401
                                  check_host_callbacks,
                                  check_key_discipline, check_wide_dtypes,
                                  iter_eqns, op_counts, op_report)
from repro.analysis.opbudget import (OpBudget,  # noqa: F401
                                     check_rotation_budget,
                                     rotation_budget)
from repro.analysis.provenance import wire_mark  # noqa: F401
from repro.analysis.sentinel import RecompileSentinel  # noqa: F401
from repro.analysis.wire import (WireTaintDomain,  # noqa: F401
                                 check_wire_truth, collect_wire_facts)
