"""Static analysis for traced federated rounds — jaxpr + AST invariants.

Two halves (see ``README.md`` § Static analysis):

* jaxpr analyzers (:mod:`repro.analysis.jaxpr`, ``opbudget``, ``donation``,
  ``sentinel``) — walk the closed jaxpr / lowered HLO of every registry
  algorithm's round, via ``RoundEngine.traced_round()`` / ``traced_chunk()``.
* AST repo rules (:mod:`repro.analysis.astlint`) — source-level checks over
  ``src/repro/``.

``python -m repro.analysis.lint`` runs everything over the full
algorithm × codec matrix and writes ``ANALYSIS.json``. Keep this package
__init__ import-light: ``compression.pipeline`` imports ``opbudget`` at
instance-construction time, so pulling registries in here would be a cycle.
"""
from repro.analysis.jaxpr import (Violation, analyze_jaxpr,  # noqa: F401
                                  check_host_callbacks,
                                  check_key_discipline, check_wide_dtypes,
                                  iter_eqns, op_counts, op_report)
from repro.analysis.opbudget import (OpBudget,  # noqa: F401
                                     check_rotation_budget,
                                     rotation_budget)
from repro.analysis.sentinel import RecompileSentinel  # noqa: F401
