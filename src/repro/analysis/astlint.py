"""AST repo-rule lint over ``src/repro/`` — the python half of the gate.

The jaxpr analyzers see what a trace *produced*; these rules catch what the
source says before a trace ever runs:

  ``R001 host-call-in-traced``  no ``np.random`` / ``time.time()`` /
      ``datetime.now()`` inside traced bodies in ``core/`` / ``fed/`` — a
      host RNG or clock read inside a jitted round body is baked in as a
      trace-time constant (silently frozen) rather than per-call behavior.
  ``R002 unresolved-spec``  codec / participation spec-string literals
      (``uplink=...``, ``codec_up=...``, ``participation=...``) must
      resolve in their registries — a typo'd spec name should fail lint,
      not the first experiment that exercises that config path.
  ``R003 metrics-schema``  an algorithm's ``metrics = {...}`` dict literal
      must cover :data:`repro.fed.api.METRIC_KEYS` — a missing schema key
      silently becomes its default in ``normalize_metrics`` and poisons
      equal-bits / equal-time comparisons.
  ``R004 unused-import``  no unused imports outside ``__init__.py``
      re-export surfaces (``# noqa`` opts a line out) — the ruff ``F401``
      baseline, checkable without ruff installed.

A **traced body** for R001 is a function decorated with ``jit``, named
``device_round``, passed by name to ``jax.jit`` / ``jax.lax.scan`` /
``while_loop`` / ``cond`` / ``fori_loop``, or any function nested inside
one of those.

:func:`lint_path` walks a tree; :func:`lint_source` checks one buffer (the
mutation fixtures in the tests feed seeded-violation sources through it).
"""
from __future__ import annotations

import ast
import os
from typing import List, Optional, Sequence, Set

from repro.analysis.jaxpr import Violation

# R001 ----------------------------------------------------------------------

_TRACER_CALLS = {"jit", "scan", "while_loop", "cond", "fori_loop",
                 "checkpoint", "remat", "vmap", "pmap", "shard_map"}
_HOST_CALLS = {
    ("time", "time"), ("time", "perf_counter"), ("time", "monotonic"),
    ("datetime", "now"), ("datetime", "utcnow"),
}
_HOST_RNG_ROOTS = {("np", "random"), ("numpy", "random")}


def _attr_chain(node) -> List[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    else:
        return []
    return parts[::-1]


def _decorated_jit(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        for node in ast.walk(dec):
            if isinstance(node, ast.Attribute) and node.attr == "jit":
                return True
            if isinstance(node, ast.Name) and node.id == "jit":
                return True
    return False


def _names_passed_to_tracers(tree: ast.AST) -> Set[str]:
    """Function names that appear as arguments to jit/scan/cond/... calls
    anywhere in the module (that's how inner scan bodies get traced)."""
    out: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain or chain[-1] not in _TRACER_CALLS:
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Name):
                out.add(arg.id)
    return out


def _check_traced_bodies(tree: ast.AST, path: str) -> List[Violation]:
    traced_names = _names_passed_to_tracers(tree)
    out: List[Violation] = []

    def visit(node: ast.AST, in_traced: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            traced = (in_traced or _decorated_jit(node)
                      or node.name == "device_round"
                      or node.name in traced_names)
            for child in ast.iter_child_nodes(node):
                visit(child, traced)
            return
        if in_traced:
            chain = _attr_chain(node) if isinstance(node,
                                                    ast.Attribute) else []
            if len(chain) >= 2 and tuple(chain[:2]) in _HOST_RNG_ROOTS:
                out.append(Violation(
                    "R001:host-call-in-traced",
                    f"{path}:{node.lineno}",
                    f"host RNG `{'.'.join(chain)}` inside a traced body — "
                    f"use jax.random with the round key"))
            if isinstance(node, ast.Call):
                cchain = _attr_chain(node.func)
                if len(cchain) >= 2 and tuple(cchain[-2:]) in _HOST_CALLS:
                    out.append(Violation(
                        "R001:host-call-in-traced",
                        f"{path}:{node.lineno}",
                        f"host clock `{'.'.join(cchain)}()` inside a traced "
                        f"body — value freezes at trace time"))
        for child in ast.iter_child_nodes(node):
            visit(child, in_traced)

    visit(tree, False)
    return out


# R002 ----------------------------------------------------------------------

_CODEC_KWARGS = {"uplink", "downlink", "codec_up", "codec_down"}
_PART_KWARGS = {"participation"}


def _spec_name(spec: str) -> str:
    return spec.split(":", 1)[0].strip()


def _registry_names():
    from repro.compression.codecs import registered_codecs
    from repro.fed.population import registered_participations
    return set(registered_codecs()), set(registered_participations())


def _spec_strings(value: ast.AST):
    """Spec string literals in a kwarg value: a Constant str, or the values
    of a per-client-group dict literal."""
    if isinstance(value, ast.Constant) and isinstance(value.value, str):
        yield value.value, value.lineno
    elif isinstance(value, ast.Dict):
        for v in value.values:
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                yield v.value, v.lineno


def _check_spec_strings(tree: ast.AST, path: str) -> List[Violation]:
    codecs, parts = _registry_names()
    out: List[Violation] = []

    def judge(kwarg: str, spec: str, lineno: int) -> None:
        if not spec:
            return   # "" = use the algorithm's historical default
        names = parts if kwarg in _PART_KWARGS else codecs
        if _spec_name(spec) not in names:
            kind = ("participation" if kwarg in _PART_KWARGS else "codec")
            out.append(Violation(
                "R002:unresolved-spec", f"{path}:{lineno}",
                f"{kind} spec {spec!r} (kwarg {kwarg}=) does not resolve: "
                f"{_spec_name(spec)!r} not in {sorted(names)}"))

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg in _CODEC_KWARGS | _PART_KWARGS:
                    for spec, ln in _spec_strings(kw.value):
                        judge(kw.arg, spec, ln)
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            tgt = node.target
            if (isinstance(tgt, ast.Name)
                    and tgt.id in _CODEC_KWARGS | _PART_KWARGS):
                for spec, ln in _spec_strings(node.value):
                    judge(tgt.id, spec, ln)
    return out


# R003 ----------------------------------------------------------------------

def _check_metrics_schema(tree: ast.AST, path: str) -> List[Violation]:
    from repro.fed.api import METRIC_KEYS
    out: List[Violation] = []
    # only the dict an algorithm's round RETURNS is schema-bound — partial
    # dicts inside train steps / harness accumulators are not
    round_fns = [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
                 and n.name in ("round", "device_round")]
    for fn in round_fns:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            if not any(isinstance(t, ast.Name) and t.id == "metrics"
                       for t in node.targets):
                continue
            if not isinstance(node.value, ast.Dict):
                continue
            keys = node.value.keys
            if any(k is None for k in keys):
                continue   # {**base, ...} extends an already-complete dict
            lit = {k.value for k in keys
                   if isinstance(k, ast.Constant)
                   and isinstance(k.value, str)}
            missing = [k for k in METRIC_KEYS if k not in lit]
            if missing:
                out.append(Violation(
                    "R003:metrics-schema", f"{path}:{node.lineno}",
                    f"metrics dict literal missing schema keys {missing} "
                    f"(METRIC_KEYS) — normalize_metrics will silently "
                    f"default them"))
    return out


# R004 ----------------------------------------------------------------------

def _noqa_lines(source: str) -> Set[int]:
    return {i + 1 for i, line in enumerate(source.splitlines())
            if "# noqa" in line}


def _check_unused_imports(tree: ast.AST, source: str, path: str,
                          ) -> List[Violation]:
    noqa = _noqa_lines(source)
    imported = []   # (local_name, shown_name, lineno)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                local = (a.asname or a.name).split(".")[0]
                imported.append((local, a.name, node.lineno))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name == "*":
                    continue
                imported.append((a.asname or a.name, a.name, node.lineno))
    used: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Attribute):
            chain = _attr_chain(node)
            if chain:
                used.add(chain[0])
        elif (isinstance(node, ast.Constant)
              and isinstance(node.value, str)):
            used.add(node.value)   # covers __all__ = ["name"] re-exports
    out = []
    for local, shown, lineno in imported:
        if local not in used and lineno not in noqa:
            out.append(Violation(
                "R004:unused-import", f"{path}:{lineno}",
                f"`{shown}` imported but unused"))
    return out


# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<buffer>",
                rules: Optional[Sequence[str]] = None) -> List[Violation]:
    """Run the repo rules on one source buffer. ``rules`` filters by rule
    id prefix (e.g. ``["R001"]``); default = the rules that apply to the
    file's location (R001 only under ``core/`` / ``fed/``; R004 not on
    ``__init__.py``)."""
    tree = ast.parse(source, filename=path)
    norm = path.replace(os.sep, "/")
    if rules is None:
        rules = ["R002", "R003"]
        if "/core/" in norm or "/fed/" in norm or norm.startswith(
                ("core/", "fed/")):
            rules.append("R001")
        if not norm.endswith("__init__.py"):
            rules.append("R004")
    out: List[Violation] = []
    if "R001" in rules:
        out += _check_traced_bodies(tree, path)
    if "R002" in rules:
        out += _check_spec_strings(tree, path)
    if "R003" in rules:
        out += _check_metrics_schema(tree, path)
    if "R004" in rules:
        out += _check_unused_imports(tree, source, path)
    return out


def lint_path(root: str) -> List[Violation]:
    """Lint every ``*.py`` under ``root`` with the default per-location
    rule set; returns the combined violation list."""
    out: List[Violation] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                src = f.read()
            try:
                out += lint_source(src, path)
            except SyntaxError as e:
                out.append(Violation("R000:syntax", f"{path}:{e.lineno}",
                                     str(e.msg)))
    return out
