"""Generalized op-budget audit: trace-time structural counters + budgets.

PR 1 introduced a bespoke ``RotationStats`` counter inside
``compression/pipeline.py`` to pin the rotated-exchange invariant (``s + 1``
forward / ``s + 1`` inverse full-model rotation passes per QuAFL round).
This module is its promoted, general home: :class:`OpBudget` is the same
trace-time counter idea (counts are *structural* — incremented while python
builds the trace, so they are data-independent and free at runtime) behind
named counters, and :func:`check_rotation_budget` re-traces a round and
judges the counts against the declared budget, returning analyzer
:class:`~repro.analysis.jaxpr.Violation` records instead of bare asserts.

The jaxpr-level half of the budget — transfer / ``convert_element_type`` /
collective counts, which make e.g. the known fp32 re-gather after
``psum_scatter`` visible as a counted quantity — comes from
:func:`repro.analysis.jaxpr.op_report` and is merged into the same report
by :func:`op_budget_report`.

``ExchangePipeline`` keeps exposing the counter as ``pipeline.stats`` with
the legacy ``.fwd`` / ``.inv`` / ``.reset()`` surface, so existing tests
and any external consumers are unaffected by the promotion.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.analysis.jaxpr import Violation, collective_bytes, op_report

# counter names the rotation audit uses
ROT_FWD = "rotation_fwd"
ROT_INV = "rotation_inv"


@dataclass
class OpBudget:
    """Named trace-time structural counters.

    Drop-in replacement for the old ``RotationStats``: ``.fwd`` / ``.inv``
    read and write the ``rotation_fwd`` / ``rotation_inv`` counters (so
    ``stats.fwd += m`` call sites and tests keep working verbatim), while
    arbitrary additional counters go through :meth:`add` / :meth:`get`.
    """
    counters: Dict[str, int] = field(default_factory=dict)

    def add(self, name: str, k: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + int(k)

    def get(self, name: str) -> int:
        return self.counters.get(name, 0)

    def reset(self) -> None:
        self.counters.clear()

    # legacy RotationStats surface -----------------------------------------
    @property
    def fwd(self) -> int:
        return self.get(ROT_FWD)

    @fwd.setter
    def fwd(self, v: int) -> None:
        self.counters[ROT_FWD] = int(v)

    @property
    def inv(self) -> int:
        return self.get(ROT_INV)

    @inv.setter
    def inv(self, v: int) -> None:
        self.counters[ROT_INV] = int(v)

    def expect(self, where: str,
               budget: Dict[str, int]) -> List[Violation]:
        """Judge the current counters against ``budget`` (exact match per
        named counter); returns one violation per blown counter."""
        out = []
        for name, want in budget.items():
            got = self.get(name)
            if got != want:
                out.append(Violation(
                    "op-budget", where,
                    f"counter {name!r}: {got} != budgeted {want}"))
        return out


def check_collective_bytes(closed, where: str,
                           caps: Dict[str, int]) -> List[Violation]:
    """Judge a trace's per-device collective payload
    (:func:`repro.analysis.jaxpr.collective_bytes`) against byte CAPS —
    upper bounds, not exact counts, because scalar side-channel rows may
    legitimately come and go. One violation per blown cap; a cap on a key
    the trace never produces passes vacuously (0 bytes moved)."""
    rep = collective_bytes(closed)
    out = []
    for key, cap in caps.items():
        got = rep.get(key, 0)
        if got > cap:
            out.append(Violation(
                "collective-bytes", where,
                f"{key}: {got} B moved exceeds budget {cap} B"))
    return out


def rotation_budget(s: int) -> Dict[str, int]:
    """The rotated-exchange contract per QuAFL round: one shared forward
    rotation feeds every uplink encode (clients reply in rotated space) and
    the s+1 averaged states rotate back once — ``s + 1`` fwd (s client
    encodes + the cached rotated-server downlink) / ``s + 1`` inv."""
    return {ROT_FWD: s + 1, ROT_INV: s + 1}


def _unjitted_round(alg):
    """The algorithm's round body as plain python, so tracing it ALWAYS
    re-runs the body and re-increments the trace-time counters — a jitted
    (or jit-forwarding) method whose (self, avals) signature is already in
    the pjit trace cache would skip the python body entirely."""
    for name in ("device_round", "round"):
        fn = getattr(type(alg), name, None)
        raw = getattr(fn, "__wrapped__", None)
        if raw is not None:
            # jitted method with static self (``@partial(jax.jit,
            # static_argnums=0)``) — rebind
            return lambda st, d, k: raw(alg, st, d, k)
    return getattr(alg, "device_round", None) or alg.round


def measure_round_counters(alg, state, data, key) -> Optional[OpBudget]:
    """Trace one round of ``alg`` and return the pipeline counters it
    incremented, or None when the algorithm has no counted pipeline."""
    import jax
    pipe = getattr(alg, "pipeline", None)
    stats = getattr(pipe, "stats", None)
    if stats is None:
        return None
    saved = dict(getattr(stats, "counters", {}))
    stats.reset()
    try:
        jax.eval_shape(_unjitted_round(alg), state, data, key)
        measured = OpBudget(dict(stats.counters))
    finally:
        stats.counters = saved
    return measured


def check_rotation_budget(alg, state, data, key, where: str,
                          budget: Optional[Dict[str, int]] = None,
                          ) -> List[Violation]:
    """Re-trace one round and audit the rotation-pass counters against the
    budget (default: :func:`rotation_budget` for the algorithm's ``s``).
    Algorithms without a counted pipeline pass vacuously."""
    measured = measure_round_counters(alg, state, data, key)
    if measured is None:
        return []
    if budget is None:
        budget = rotation_budget(int(alg.fed.s))
    return measured.expect(where, budget)


def op_budget_report(alg, state, data, key, closed) -> Dict[str, int]:
    """Merged structural report: jaxpr-level tracked op counts plus the
    pipeline's trace-time rotation counters (when present)."""
    rep = dict(op_report(closed))
    measured = measure_round_counters(alg, state, data, key)
    if measured is not None:
        rep.update({k: v for k, v in measured.counters.items()})
    return rep
