"""Jaxpr-level invariant analyzers for traced federated rounds.

The repo's correctness story rests on properties of the TRACED program, not
the python that builds it: a round is one device-resident computation (no
host callbacks mid-scan), arithmetic stays in the f32 regime the bit
accounting assumes, and PRNG keys are consumed once per derivation path so
client schedules survive resharding. This module walks closed jaxprs (from
:meth:`repro.fed.engine.RoundEngine.traced_round` / ``traced_chunk``) and
checks each of those invariants mechanically.

Every checker returns a list of :class:`Violation` — empty means clean.
:func:`analyze_jaxpr` bundles all jaxpr checks plus an op-count report
(consumed by :mod:`repro.analysis.opbudget`).

**Key-discipline policy.** The lattice exchange *intentionally* consumes one
key twice with the SAME derivation — shared-randomness dithers: the decoder
re-splits the encoder's key to reproduce its rotation/dither draws (see
``LatticeQuantizer.decode``). Statically, identical (primitive, params,
output-aval) consumption signatures are therefore the shared-randomness
idiom, not a bug. What corrupts schedules is a key consumed by two
*distinct* derivations — e.g. ``uniform(k, (8,))`` and ``normal(k, (4,))``
— which silently correlates two streams. So the rule is: flag a key var
only when its consumption signatures (over ``random_bits``/``random_split``)
are distinct; ``random_fold_in`` never flags (folding is domain separation —
the canonical FIX for reuse).
"""
from __future__ import annotations

import dataclasses
import math
from collections import Counter, defaultdict
from typing import Any, Dict, Iterator, List, Tuple

from jax import dtypes
from jax.core import ClosedJaxpr, Jaxpr, Literal


@dataclasses.dataclass(frozen=True)
class Violation:
    """One analyzer finding: ``rule`` id, ``where`` it was found (e.g.
    ``"quafl×lattice/traced_round"``), human-readable ``detail``."""
    rule: str
    where: str
    detail: str

    def as_dict(self) -> Dict[str, str]:
        return {"rule": self.rule, "where": self.where,
                "detail": self.detail}


# ---------------------------------------------------------------------------
# generic recursion over sub-jaxprs
# ---------------------------------------------------------------------------

def _jaxprs_in(v) -> Iterator[Jaxpr]:
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _jaxprs_in(x)


def subjaxprs(eqn) -> Iterator[Jaxpr]:
    """All jaxprs nested in an equation's params (pjit ``jaxpr``, scan
    ``jaxpr``, cond ``branches``, while ``cond_jaxpr``/``body_jaxpr``,
    shard_map ``jaxpr``, custom_* ``call_jaxpr``/``jvp_jaxpr_fun`` ...)."""
    for v in eqn.params.values():
        yield from _jaxprs_in(v)


def iter_eqns(jaxpr: Jaxpr) -> Iterator[Any]:
    """Depth-first iterator over every equation, descending into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for j in subjaxprs(eqn):
            yield from iter_eqns(j)


def _as_jaxpr(j) -> Jaxpr:
    return j.jaxpr if isinstance(j, ClosedJaxpr) else j


# ---------------------------------------------------------------------------
# host callbacks / debug prints in the hot path
# ---------------------------------------------------------------------------

CALLBACK_PRIMS = frozenset({
    "debug_callback", "pure_callback", "io_callback", "callback",
    "outside_call", "host_callback_call", "infeed", "outfeed",
    "debug_print",
})


def check_host_callbacks(closed, where: str) -> List[Violation]:
    """No host round-trips inside a traced round: ``jax.debug.print``,
    ``pure_callback`` etc. serialize the device stream and break the
    one-sync-per-chunk contract of the scanned engine."""
    out = []
    for eqn in iter_eqns(_as_jaxpr(closed)):
        if eqn.primitive.name in CALLBACK_PRIMS:
            out.append(Violation(
                "host-callback", where,
                f"host callback primitive {eqn.primitive.name!r} in traced "
                f"round body"))
    return out


# ---------------------------------------------------------------------------
# implicit f64 / wide-dtype promotion
# ---------------------------------------------------------------------------

WIDE_DTYPES = ("float64", "complex128")


def check_wide_dtypes(closed, where: str) -> List[Violation]:
    """No f64/c128 values anywhere in the trace — the wire accounting and
    the Pallas kernels assume the f32 regime; a weak-type promotion to f64
    silently doubles buffer sizes and invalidates ``bits_*`` metrics."""
    out = []
    seen = set()
    for eqn in iter_eqns(_as_jaxpr(closed)):
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            dt = str(getattr(aval, "dtype", ""))
            if dt in WIDE_DTYPES and dt not in seen:
                seen.add(dt)
                out.append(Violation(
                    "wide-dtype", where,
                    f"{dt} value produced by {eqn.primitive.name!r} "
                    f"({aval}) — implicit 64-bit promotion in traced round"))
    return out


# ---------------------------------------------------------------------------
# PRNG-key discipline
# ---------------------------------------------------------------------------

_DRAW = frozenset({"random_bits"})
_SPLIT = frozenset({"random_split"})
_FOLD = frozenset({"random_fold_in"})
_ALIAS = frozenset({"random_wrap", "random_unwrap"})
_CONSUMERS = _DRAW | _SPLIT

# jax.random's composite rejection samplers consume one key several ways
# internally (knuth vs rejection branches, both materialized under vmap via
# select_n) — BY DESIGN, per-lane exclusive. From the caller's perspective
# each is ONE draw: treat the jitted helper as an atomic consumer and do
# not descend.
_ATOMIC_SAMPLERS = frozenset({
    "_poisson", "_poisson_knuth", "_poisson_rejection",
    "_gamma", "_gamma_impl", "_gamma_one", "_gamma_grad",
    "_binomial", "_binomial_inversion", "_binomial_btrs",
})


def _consume_sig(eqn) -> str:
    """Signature of a key consumption: primitive + params + output avals.
    Two consumptions with the SAME signature produce identical streams —
    that's the shared-randomness idiom; DISTINCT signatures on one key are
    two correlated-but-different streams, i.e. the bug."""
    params = sorted((k, repr(v)) for k, v in eqn.params.items())
    outs = ",".join(str(getattr(v, "aval", "?")) for v in eqn.outvars)
    return f"{eqn.primitive.name}{params!r}->{outs}"


def _is_key_var(var) -> bool:
    aval = getattr(var, "aval", None)
    try:
        return aval is not None and dtypes.issubdtype(aval.dtype,
                                                      dtypes.prng_key)
    except (TypeError, AttributeError):
        return False


def _key_usage(jaxpr: Jaxpr, memo) -> Tuple[List[Tuple[str, List[str]]],
                                            Dict[int, Counter]]:
    """Per-jaxpr key-consumption analysis.

    Returns ``(violations, invar_sigs)`` where ``violations`` are
    ``(varname, [distinct sigs])`` pairs and ``invar_sigs`` maps an invar
    POSITION to the Counter of consumption signatures that flow from it —
    so a caller can propagate a sub-jaxpr's consumption onto the operands
    it passed in (this is what catches reuse across a ``scan``/``cond``
    boundary).
    """
    if id(jaxpr) in memo:
        return memo[id(jaxpr)]
    rep: Dict[Any, Any] = {}   # wrap/unwrap alias chains -> representative
    # a raw uint32 seed wrapped via random_wrap IS a key for discipline
    # purposes — remember representatives whose alias chain touches a key
    keyish: set = set()

    def find(v):
        while v in rep:
            v = rep[v]
        return v

    use: Dict[Any, Counter] = defaultdict(Counter)
    viols: List[Tuple[str, List[str]]] = []

    def charge(var, sig, count=1):
        if not isinstance(var, Literal):
            use[find(var)][sig] += count

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in _ALIAS:
            src = eqn.invars[0]
            if not isinstance(src, Literal):
                r = find(src)
                rep[eqn.outvars[0]] = r
                if _is_key_var(eqn.outvars[0]) or _is_key_var(src):
                    keyish.add(r)
            continue
        if name in _CONSUMERS:
            charge(eqn.invars[0], _consume_sig(eqn))
            continue
        if name in _FOLD:
            # fold_in is domain separation: never a violation, and the
            # folded OUTPUT is a fresh derivation path.
            continue
        if (name == "pjit"
                and str(eqn.params.get("name", "")) in _ATOMIC_SAMPLERS):
            outs = ",".join(str(getattr(v, "aval", "?"))
                            for v in eqn.outvars)
            sig = f"sampler:{eqn.params['name']}->{outs}"
            for v in eqn.invars:
                if not isinstance(v, Literal) and _is_key_var(v):
                    charge(v, sig)
            continue
        subs = list(subjaxprs(eqn))
        if not subs:
            continue
        if eqn.primitive.name == "cond":
            # branches are ALTERNATIVES: exactly one executes, so the same
            # key consumed differently by different branches is NOT reuse
            # (jax.random.poisson does exactly this internally). Collapse
            # each operand's cross-branch consumption into one synthetic
            # signature — outer consumption of the same key still collides
            # with it, and within-branch reuse is judged inside the branch.
            ops = list(eqn.invars)[1:]
            branch_sigs: Dict[int, set] = defaultdict(set)
            for sub in subs:
                sviols, sigs = _key_usage(sub, memo)
                viols.extend(sviols)
                for pos, cnt in sigs.items():
                    branch_sigs[pos].update(cnt)
            for pos, sigset in branch_sigs.items():
                if pos < len(ops):
                    charge(ops[pos], f"cond({'|'.join(sorted(sigset))})")
            continue
        # map each sub-jaxpr invar position onto the eqn operand feeding it
        for sub, operands in _operand_maps(eqn, subs):
            sviols, sigs = _key_usage(sub, memo)
            viols.extend(sviols)
            for pos, cnt in sigs.items():
                if pos < len(operands) and operands[pos] is not None:
                    for sig, c in cnt.items():
                        charge(operands[pos], sig, c)

    for var, cnt in use.items():
        distinct = sorted(cnt)
        if len(distinct) >= 2 and (_is_key_var(var) or var in keyish):
            viols.append((str(var), [s[:120] for s in distinct]))

    invar_sigs: Dict[int, Counter] = {}
    for i, v in enumerate(jaxpr.invars):
        r = find(v)
        acc = Counter()
        for var, cnt in use.items():
            if var is r:
                acc.update(cnt)
        if acc:
            invar_sigs[i] = acc
    memo[id(jaxpr)] = (viols, invar_sigs)
    return viols, invar_sigs


def _operand_maps(eqn, subs):
    """Yield ``(sub_jaxpr, operands)`` where ``operands[i]`` is the eqn
    invar feeding sub-jaxpr invar ``i`` (None where unmapped). Handles the
    control-flow primitives whose operand layout is not positional."""
    name = eqn.primitive.name
    inv = list(eqn.invars)
    # (cond is handled by the caller — its branches are alternatives)
    if name == "while":
        cn = eqn.params.get("cond_nconsts", 0)
        bn = eqn.params.get("body_nconsts", 0)
        carry = inv[cn + bn:]
        cond_j, body_j = subs[0], subs[1] if len(subs) > 1 else subs[0]
        yield cond_j, inv[:cn] + carry
        yield body_j, inv[cn:cn + bn] + carry
        return
    # scan: invars = consts + init + xs and body invars = consts + carry + x
    # line up positionally (xs map to their stacked operand, which is the
    # right identity for reuse tracking). pjit/closed_call/shard_map are
    # positional too. Anything whose arity does not line up (custom_jvp /
    # custom_vjp carry extra tangent/residual jaxprs) is NOT mapped — the
    # sub-jaxpr is still analyzed internally, but its consumption is not
    # charged to outer operands (conservative: may miss cross-boundary
    # reuse there, never false-positives).
    for sub in subs:
        if len(sub.invars) == len(inv):
            yield sub, inv
        else:
            yield sub, []


def check_key_discipline(closed, where: str) -> List[Violation]:
    """Flag any PRNG key var consumed by two DISTINCT random derivations.

    Identical consumption signatures (same primitive, params, and output
    avals) are permitted — the lattice shared-dither idiom re-derives the
    encoder's randomness by design. ``fold_in`` never flags.
    """
    viols, _ = _key_usage(_as_jaxpr(closed), {})
    # a shared sub-jaxpr (jit-cached helper) can be reached through several
    # parents; report each distinct finding once
    seen = set()
    out = []
    for var, sigs in viols:
        k = (var, tuple(sigs))
        if k in seen:
            continue
        seen.add(k)
        out.append(Violation(
            "key-reuse", where,
            f"key {var} consumed by {len(sigs)} distinct random "
            f"derivations: {sigs}"))
    return out


# ---------------------------------------------------------------------------
# op-count report (consumed by the op-budget audit)
# ---------------------------------------------------------------------------

# primitives whose counts the budget/watchdog report tracks explicitly
TRACKED_OPS = ("convert_element_type", "device_put",
               "psum_scatter", "reduce_scatter", "all_gather", "all_reduce",
               "ppermute", "psum")


def op_counts(closed) -> Counter:
    """Counter of every primitive in the (recursively walked) jaxpr."""
    return Counter(e.primitive.name for e in iter_eqns(_as_jaxpr(closed)))


# collectives whose per-device payload the report estimates: gathers charge
# their OUTPUT avals (bytes every device receives), reductions their INPUT
# avals (bytes every device contributes)
_GATHER_OPS = frozenset({"all_gather"})
_REDUCE_OPS = frozenset({"psum", "psum_scatter", "reduce_scatter",
                         "all_reduce", "ppermute"})


def collective_bytes(closed) -> Dict[str, int]:
    """Per-device moved-bytes estimate for every collective in the trace,
    split by element kind: ``<prim>_fbytes`` (float payload) vs
    ``<prim>_ibytes`` (integer codes). This is the quantity a regression
    from the coded redistribution back to an fp32 re-gather inflates by
    ~d·4 — counts alone cannot see it (same number of ``all_gather`` eqns,
    radically different wire)."""
    out: Dict[str, int] = {}
    for eqn in iter_eqns(_as_jaxpr(closed)):
        name = eqn.primitive.name
        if name in _GATHER_OPS:
            vs = eqn.outvars
        elif name in _REDUCE_OPS:
            vs = eqn.invars
        else:
            continue
        for v in vs:
            aval = getattr(v, "aval", None)
            dt = getattr(aval, "dtype", None)
            if dt is None or not hasattr(dt, "itemsize"):
                continue
            kind = "f" if getattr(dt, "kind", "") == "f" else "i"
            key = f"{name}_{kind}bytes"
            out[key] = out.get(key, 0) + (int(math.prod(aval.shape))
                                          * int(dt.itemsize))
    return out


def op_report(closed) -> Dict[str, int]:
    """The tracked subset of :func:`op_counts` plus the per-collective
    moved-bytes estimate and total eqn count — transfer/convert and
    collective traffic that make e.g. the known fp32 re-gather after
    ``psum_scatter`` visible as a counted AND sized quantity."""
    c = op_counts(closed)
    rep = {k: c[k] for k in TRACKED_OPS if c[k]}
    rep.update(collective_bytes(closed))
    rep["eqns_total"] = sum(c.values())
    return rep


def analyze_jaxpr(closed, where: str) -> Tuple[List[Violation],
                                               Dict[str, int]]:
    """All jaxpr invariant checks on one closed jaxpr + its op report."""
    viols = (check_host_callbacks(closed, where)
             + check_wide_dtypes(closed, where)
             + check_key_discipline(closed, where))
    return viols, op_report(closed)
