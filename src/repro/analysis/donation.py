"""Donation audit: does the compiled executable actually alias what we donate?

``RoundEngine.run_chunk`` donates the input state (``donate_argnums=(0,)``)
so a d=2^20 chunk entry holds ONE state generation instead of two. But
donation is a *request*: XLA only honors it when an output with matching
shape/dtype/layout exists, and silently falls back to copying otherwise —
exactly the kind of regression (a dtype change in one state leaf, a new
non-carried output) that nothing would catch until peak memory doubles at
scale. This auditor compiles the chunk program under the same donation
contract and checks the executable's ``input_output_alias`` table against
the donation *intent* recorded in the lowered HLO (``tf.aliasing_output``
attributes) and the number of donated state leaves.

Counts (not parameter numbers) are compared because jit's default
``keep_unused=False`` prunes unused params and renumbers the rest.
"""
from __future__ import annotations

import re
from typing import Dict, List

import jax

from repro.analysis.jaxpr import Violation


def _alias_entries(compiled_text: str) -> int:
    """Number of entries in the executable's ``input_output_alias`` table.

    HLO prints it as ``input_output_alias={ {out_idx}: (param, {idx},
    may-alias), ... }`` — entries nest one brace level, so the table is
    matched with an explicit one-level-nesting pattern and entries are
    counted by their ``{out}: (param,`` heads.
    """
    m = re.search(r"input_output_alias=\{((?:[^{}]|\{[^{}]*\})*)\}",
                  compiled_text, re.S)
    if not m:
        return 0
    return len(re.findall(r"\}\s*:\s*\(\s*\d+\s*,", m.group(1)))


def _intent_entries(lowered_text: str) -> int:
    """Number of ``tf.aliasing_output`` markers in the lowered stableHLO —
    the donation intent jit recorded before XLA decided anything."""
    return len(re.findall(r"tf\.aliasing_output", lowered_text))


def audit_lowered(lowered, n_donated_leaves: int, where: str,
                  ) -> List[Violation]:
    """Audit one ``jax.jit(..., donate_argnums=...).lower(...)`` result.

    Checks (a) the lowering recorded donation intent for every donated leaf
    and (b) the compiled executable's input-output aliasing honored every
    one of them. Returns violations for any shortfall.
    """
    out: List[Violation] = []
    intent = _intent_entries(lowered.as_text())
    if intent < n_donated_leaves:
        out.append(Violation(
            "donation-intent", where,
            f"only {intent}/{n_donated_leaves} donated state leaves carry "
            f"donation intent in the lowered HLO (donated buffer unused or "
            f"argnum mismatch)"))
    compiled = lowered.compile()
    aliased = _alias_entries(compiled.as_text())
    if aliased < intent:
        out.append(Violation(
            "donation-dropped", where,
            f"XLA honored {aliased}/{intent} requested donations — "
            f"shape/dtype/layout mismatch between a donated input and every "
            f"output (silent copy; peak memory holds both generations)"))
    return out


def audit_engine_chunk(engine, state, data, key, length: int,
                       where: str) -> List[Violation]:
    """Audit the engine's scanned chunk donation for one chunk length."""
    leaves = len(jax.tree_util.tree_leaves(state))
    lowered = engine.lowered_chunk(state, data, key, length)
    return audit_lowered(lowered, leaves, where)


def donation_report(engine, state, data, key, length: int) -> Dict[str, int]:
    """Raw counts (state leaves / intent markers / honored aliases) for the
    machine-readable report."""
    lowered = engine.lowered_chunk(state, data, key, length)
    return {
        "state_leaves": len(jax.tree_util.tree_leaves(state)),
        "donation_intent": _intent_entries(lowered.as_text()),
        "aliased": _alias_entries(lowered.compile().as_text()),
    }
