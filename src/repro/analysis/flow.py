"""Generic forward dataflow engine over closed jaxprs.

PR 8/9 analyzers *count* things (ops, bytes, key uses) by walking eqns;
this module *derives facts along dataflow edges*: a configurable abstract
domain (lattice values + join + per-primitive transfer functions) is
propagated forward through a closed jaxpr by a worklist/fixpoint
interpreter that understands the control primitives jax actually emits:

  - ``pjit`` / call-like primitives: recurse into the subjaxpr (with an
    optional precise *call override* so a domain can summarise a known
    callee, e.g. ``jnp.mod``'s ``remainder`` wrapper, more tightly than
    its body).
  - ``scan``: iterate the body to a fixpoint on the carry values (join
    per iteration, widening to top after ``max_fixpoint_iters``), then a
    final observed pass so analyzer hooks see post-fixpoint facts once.
  - ``while``: same carry fixpoint through the body; the cond jaxpr is
    analyzed for its observations only.
  - ``cond``: analyze every branch with the same operand facts and join
    the branch outputs (branches are alternatives, not sequences).
  - ``shard_map``: delegate entry/exit value mapping to the domain so a
    mesh-aware analysis (e.g. divergence) can seed per-axis facts from
    ``in_names`` and audit escapes against ``out_names``.

Domains subclass :class:`FlowDomain`; analyzers live in ``wire.py``,
``intervals.py`` and ``divergence.py``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

from jax._src import core as jcore

# Primitives whose params hold a single positionally-compatible subjaxpr.
_CALL_JAXPR_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")

# Fixpoint iteration budget before widening a carry to top. Carries in
# this repo's round programs stabilise in 2-3 joins; the cap only guards
# against domains with infinite ascending chains (e.g. intervals).
MAX_FIXPOINT_ITERS = 16


class FlowDomain:
    """Abstract domain: lattice values, join, and transfer functions.

    The engine never inspects values; it only moves them around and asks
    the domain to combine them. Subclasses must implement ``top``,
    ``join`` and ``transfer``; everything else has sound defaults.
    """

    def top(self, aval) -> Any:
        raise NotImplementedError

    def join(self, a: Any, b: Any) -> Any:
        raise NotImplementedError

    def transfer(self, eqn, ins: list[Any]) -> list[Any]:
        """Abstract outputs of a non-control equation."""
        raise NotImplementedError

    def literal(self, lit) -> Any:
        """Value for a jaxpr literal operand."""
        return self.top(lit.aval)

    def const(self, aval, val) -> Any:
        """Value for a closed-jaxpr constant."""
        return self.top(aval)

    def veq(self, a: Any, b: Any) -> bool:
        """Equality used for fixpoint convergence checks."""
        return a == b

    def call_override(self, eqn, closed_sub, ins: list[Any]) -> list[Any] | None:
        """Optional precise summary for a call-like eqn; None recurses."""
        return None

    def enter_shard_map(self, eqn, ins: list[Any]) -> list[Any]:
        """Map outer operand values to body invar values."""
        return ins

    def exit_shard_map(self, eqn, outs: list[Any], ctx: FlowContext) -> list[Any]:
        """Map body output values to outer eqn output values."""
        return outs

    def on_eqn(self, eqn, ins: list[Any], outs: list[Any], ctx: FlowContext) -> None:
        """Observation hook; called exactly once per eqn per analysis."""


@dataclass
class FlowContext:
    """Mutable per-analysis state handed to domain hooks."""

    path: tuple[str, ...] = ()
    observe: bool = True
    # Scratch space for domains (e.g. collected facts/violations).
    facts: list = field(default_factory=list)

    def at(self, label: str, observe: bool | None = None) -> FlowContext:
        sub = FlowContext(
            path=self.path + (label,),
            observe=self.observe if observe is None else observe,
            facts=self.facts,
        )
        return sub

    @property
    def where(self) -> str:
        return "/".join(self.path) or "<root>"


@dataclass
class FlowResult:
    out_vals: list[Any]
    context: FlowContext


def _read(domain: FlowDomain, env: dict, atom) -> Any:
    if isinstance(atom, jcore.Literal):
        return domain.literal(atom)
    try:
        return env[atom]
    except KeyError:  # defensive: unbound var (shouldn't happen)
        return domain.top(atom.aval)


def _write(env: dict, var, val) -> None:
    if isinstance(var, jcore.DropVar):
        return
    env[var] = val


def _tops(domain: FlowDomain, eqn) -> list[Any]:
    return [domain.top(v.aval) for v in eqn.outvars]


def _closed(sub) -> jcore.ClosedJaxpr:
    if isinstance(sub, jcore.ClosedJaxpr):
        return sub
    return jcore.ClosedJaxpr(sub, ())


def analyze_flow(closed, domain: FlowDomain, inputs: list[Any] | None = None,
                 ctx: FlowContext | None = None) -> FlowResult:
    """Run ``domain`` forward over ``closed`` and return abstract outputs.

    ``inputs`` seeds the top-level invars (defaults to ``domain.top``).
    The returned context carries whatever facts the domain collected via
    ``ctx.facts`` in its ``on_eqn`` hook.
    """
    closed = _closed(closed)
    jaxpr = closed.jaxpr
    if inputs is None:
        inputs = [domain.top(v.aval) for v in jaxpr.invars]
    if len(inputs) != len(jaxpr.invars):
        raise ValueError(
            f"analyze_flow: {len(inputs)} seeds for {len(jaxpr.invars)} invars")
    ctx = ctx or FlowContext()
    env: dict = {}
    for v, val in zip(jaxpr.invars, inputs):
        _write(env, v, val)
    for cv, c in zip(jaxpr.constvars, closed.consts):
        _write(env, cv, domain.const(cv.aval, c))
    _run_block(jaxpr, env, domain, ctx)
    outs = [_read(domain, env, v) for v in jaxpr.outvars]
    return FlowResult(out_vals=outs, context=ctx)


def _run_block(jaxpr, env: dict, domain: FlowDomain, ctx: FlowContext) -> None:
    for idx, eqn in enumerate(jaxpr.eqns):
        ins = [_read(domain, env, a) for a in eqn.invars]
        outs = _eqn_outputs(eqn, ins, domain, ctx, idx)
        for v, val in zip(eqn.outvars, outs):
            _write(env, v, val)
        if ctx.observe:
            domain.on_eqn(eqn, ins, outs, ctx)


def _run_sub(sub, ins: list[Any], domain: FlowDomain, ctx: FlowContext) -> list[Any]:
    """Analyze a subjaxpr with the given invar seeds; return outvar values."""
    sub = _closed(sub)
    res = analyze_flow(sub, domain, inputs=ins, ctx=ctx)
    return res.out_vals


def _eqn_outputs(eqn, ins: list[Any], domain: FlowDomain, ctx: FlowContext,
                 idx: int) -> list[Any]:
    name = eqn.primitive.name
    if name == "scan":
        return _scan(eqn, ins, domain, ctx.at(f"scan@{idx}"))
    if name == "while":
        return _while(eqn, ins, domain, ctx.at(f"while@{idx}"))
    if name == "cond":
        return _cond(eqn, ins, domain, ctx.at(f"cond@{idx}"))
    if name == "shard_map":
        return _shard_map(eqn, ins, domain, ctx.at(f"shard_map@{idx}"))
    sub = _find_call_jaxpr(eqn)
    if sub is not None:
        closed_sub = _closed(sub)
        override = domain.call_override(eqn, closed_sub, ins)
        if override is not None:
            return override
        if len(closed_sub.jaxpr.invars) == len(ins):
            label = eqn.params.get("name", name)
            return _run_sub(closed_sub, ins, domain, ctx.at(f"{name}:{label}@{idx}"))
        return _tops(domain, eqn)  # call with odd arity: stay sound
    return domain.transfer(eqn, ins)


def _find_call_jaxpr(eqn):
    for key in _CALL_JAXPR_KEYS:
        sub = eqn.params.get(key)
        if isinstance(sub, (jcore.Jaxpr, jcore.ClosedJaxpr)):
            return sub
    return None


def _fixpoint_carry(body, consts: list[Any], carry: list[Any], extras: list[Any],
                    num_carry: int, domain: FlowDomain, ctx: FlowContext):
    """Iterate ``body`` joining the carry until stable (or widen to top).

    Returns (final_carry, final_body_outs) where final_body_outs is from
    one *observed* pass run with the post-fixpoint carry.
    """
    body = _closed(body)
    for _ in range(MAX_FIXPOINT_ITERS):
        outs = _run_sub(body, consts + carry + extras, domain,
                        ctx.at("fix", observe=False))
        new_carry = [domain.join(c, o) for c, o in zip(carry, outs[:num_carry])]
        if all(domain.veq(c, n) for c, n in zip(carry, new_carry)):
            break
        carry = new_carry
    else:
        carry = [domain.top(v.aval)
                 for v in body.jaxpr.invars[len(consts):len(consts) + num_carry]]
    outs = _run_sub(body, consts + carry + extras, domain, ctx.at("body"))
    carry = [domain.join(c, o) for c, o in zip(carry, outs[:num_carry])]
    return carry, outs


def _scan(eqn, ins: list[Any], domain: FlowDomain, ctx: FlowContext) -> list[Any]:
    n_const = eqn.params["num_consts"]
    n_carry = eqn.params["num_carry"]
    consts = ins[:n_const]
    init = ins[n_const:n_const + n_carry]
    # Per-iteration slices of the stacked xs share the stacked abstract
    # value (facts here are shape-independent).
    xs = ins[n_const + n_carry:]
    carry, outs = _fixpoint_carry(eqn.params["jaxpr"], consts, init, xs,
                                  n_carry, domain, ctx)
    ys = outs[n_carry:]
    return list(carry) + list(ys)


def _while(eqn, ins: list[Any], domain: FlowDomain, ctx: FlowContext) -> list[Any]:
    n_cc = eqn.params["cond_nconsts"]
    n_bc = eqn.params["body_nconsts"]
    cond_consts = ins[:n_cc]
    body_consts = ins[n_cc:n_cc + n_bc]
    init = ins[n_cc + n_bc:]
    carry, _ = _fixpoint_carry(eqn.params["body_jaxpr"], body_consts, init, [],
                               len(init), domain, ctx)
    # The loop may run zero times: join the fixpoint with the init values.
    carry = [domain.join(c, i) for c, i in zip(carry, init)]
    _run_sub(eqn.params["cond_jaxpr"], cond_consts + carry, domain, ctx.at("cond"))
    return carry


def _cond(eqn, ins: list[Any], domain: FlowDomain, ctx: FlowContext) -> list[Any]:
    ops = ins[1:]
    branch_outs = [
        _run_sub(br, list(ops), domain, ctx.at(f"branch[{i}]"))
        for i, br in enumerate(eqn.params["branches"])
    ]
    outs = branch_outs[0]
    for other in branch_outs[1:]:
        outs = [domain.join(a, b) for a, b in zip(outs, other)]
    return outs


def _shard_map(eqn, ins: list[Any], domain: FlowDomain, ctx: FlowContext) -> list[Any]:
    body_ins = domain.enter_shard_map(eqn, ins)
    outs = _run_sub(eqn.params["jaxpr"], body_ins, domain, ctx)
    return domain.exit_shard_map(eqn, outs, ctx)


class JoinAllDomain(FlowDomain):
    """Base for may-analyses where every output derives from the inputs.

    Default transfer joins all operand values into every output — sound
    for taint-style domains where join is set-union and literals are
    bottom. Domains needing per-primitive precision override transfer.
    """

    def transfer(self, eqn, ins: list[Any]) -> list[Any]:
        acc = self.bottom()
        for v in ins:
            acc = self.join(acc, v)
        return [acc for _ in eqn.outvars]

    def bottom(self) -> Any:
        raise NotImplementedError

    def literal(self, lit) -> Any:
        return self.bottom()

    def const(self, aval, val) -> Any:
        return self.bottom()
