"""Recompile sentinel: one compile per (algorithm, codec, chunk length).

The scanned engine's whole performance story assumes each chunk program
compiles ONCE and then replays — a retrace mid-run (weak-type drift in a
state leaf, a shape that wobbles with the round index, a python scalar
captured as a fresh constant) silently turns every chunk boundary into a
multi-second compile. The sentinel pins this two ways:

* **fingerprints** — :meth:`RecompileSentinel.record` hashes the chunk's
  (jaxpr, input avals) under a ``(algorithm, codec, chunk length)`` tag;
  a second ``record`` with a different fingerprint for the same tag is a
  violation (the program the run would compile changed mid-run).
* **jit-cache interrogation** — :meth:`RecompileSentinel.check_engine`
  reads ``fn._cache_size()`` of every cached chunk program after a run:
  1 means compiled once and replayed; >= 2 means a retrace happened.

Typical use (also what ``repro.analysis.lint`` and the pytest gate do)::

    sentinel = RecompileSentinel()
    sentinel.record(tag, engine.traced_chunk(state, data, key, K), ...)
    ... run simulate(..., scan_chunk=K) ...
    violations = sentinel.check_engine(tag, engine)
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Tuple

from repro.analysis.jaxpr import Violation


def fingerprint(closed, avals=None) -> str:
    """Stable hash of a closed jaxpr + the avals it was traced at."""
    h = hashlib.sha256()
    h.update(str(closed).encode())
    if avals is None:
        avals = [getattr(v, "aval", None) for v in closed.jaxpr.invars]
    h.update("|".join(str(a) for a in avals).encode())
    return h.hexdigest()[:16]


class RecompileSentinel:
    """Tracks one expected compilation per (algorithm, codec, chunk-length)
    tag; reports any second compilation as a violation."""

    def __init__(self):
        self._prints: Dict[Tuple, str] = {}
        self.violations: List[Violation] = []

    def record(self, tag, closed, avals=None) -> None:
        """Pin ``tag`` to the fingerprint of ``closed``; a later ``record``
        for the same tag must match or the sentinel trips."""
        fp = fingerprint(closed, avals)
        old = self._prints.get(tag)
        if old is None:
            self._prints[tag] = fp
        elif old != fp:
            self.violations.append(Violation(
                "recompile", f"{tag}",
                f"traced program changed mid-run: fingerprint {old} -> "
                f"{fp} (second compilation for this tag)"))

    def check_engine(self, tag, engine) -> List[Violation]:
        """Interrogate a :class:`~repro.fed.engine.RoundEngine`'s jit caches
        after a run: every cached chunk program must have compiled exactly
        once (``_cache_size() == 1``)."""
        out: List[Violation] = []
        for length, fn in getattr(engine, "_chunk_fns", {}).items():
            size = _cache_size(fn)
            if size is None:
                continue
            if size > 1:
                out.append(Violation(
                    "recompile", f"{tag}/chunk{length}",
                    f"chunk program compiled {size} times for one run "
                    f"(retrace mid-run: aval/weak-type drift in the carry)"))
            elif size == 0:
                out.append(Violation(
                    "recompile", f"{tag}/chunk{length}",
                    "chunk program cached but never compiled (engine "
                    "bypassed its own cache)"))
        self.violations.extend(out)
        return out

    def report(self) -> List[Violation]:
        return list(self.violations)


def _cache_size(fn):
    try:
        return fn._cache_size()
    except AttributeError:
        return None
