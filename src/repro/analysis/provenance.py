"""Wire provenance marks: a zero-cost identity primitive for message sites.

``wire_mark(x, channel=..., part=..., codec=...)`` is an identity on
``x`` that survives into the traced jaxpr, so the wire-truth audit
(``analysis/wire.py``) can locate every value the code *claims* is a
wire message and cross-check its traced dtype/shape against the codec's
machine-readable declaration. It lowers to its operand (XLA sees nothing)
and vmap rewrites ``batched=False`` to ``True`` so per-message encodes
vmapped over the message axis stay honestly described.

This module is deliberately import-light: ``repro.compression`` imports
it at module load, so it must not pull the analyzers (or jax.numpy-heavy
code) in transitively.
"""

from __future__ import annotations

from jax import core
from jax.interpreters import batching, mlir

MARK_PRIM_NAME = "wire_mark"

wire_mark_p = core.Primitive(MARK_PRIM_NAME)
wire_mark_p.def_impl(lambda x, **_: x)
wire_mark_p.def_abstract_eval(lambda x, **_: x)
mlir.register_lowering(wire_mark_p, lambda ctx, x, **_: [x])


def _batch_rule(args, dims, **params):
    (x,), (d,) = args, dims
    return wire_mark_p.bind(x, **{**params, "batched": True}), d


batching.primitive_batchers[wire_mark_p] = _batch_rule

# part names a role inside one message; side-channel rows (charged at 32
# bits each by the codec declaration) are everything except the payload.
PAYLOAD_PARTS = ("codes", "idx", "vals")
SIDE_PARTS = ("gamma", "levels", "scale")


def wire_mark(x, *, channel: str, part: str, codec: str,
              batched: bool = False, d: int = 0):
    """Mark ``x`` as the ``part`` of a ``channel`` message of ``codec``.

    channel: "up" | "down" — uplink (client→server) or downlink.
    part: "codes"/"idx"/"vals" payload, or a named side-channel row.
    batched: True when the leading axis of ``x`` is a message batch
      (one message per row); vmap sets this automatically.
    d: the model/leaf dimension this message encodes (0 = unknown). Mesh
      exchanges ship PER-LEAF messages whose element counts differ from
      the flat-model declaration; recording the encode-site dimension lets
      the wire-truth audit rebuild the codec's declaration at exactly this
      granularity instead of guessing.
    """
    return wire_mark_p.bind(x, channel=channel, part=part, codec=codec,
                            batched=batched, d=int(d))


def observe_wire(x, **kwargs):
    """Record a mark without re-routing the value (returns None).

    Use where the live value must keep its dtype but the *wire* form is a
    cast (e.g. uint32 working codes whose wire container is uint8): pass
    the cast value here; the mark stays in the jaxpr, XLA dead-codes it.
    """
    wire_mark(x, **kwargs)


def iter_marks(closed):
    """Yield (eqn, aval, params) for every wire_mark in a closed jaxpr."""
    from repro.analysis.jaxpr import iter_eqns

    for eqn in iter_eqns(closed):
        if eqn.primitive.name == MARK_PRIM_NAME:
            yield eqn, eqn.invars[0].aval, dict(eqn.params)
