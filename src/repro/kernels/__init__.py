# Pallas kernels for the paper's compute hot-spots, all swept against the
# pure-jnp oracles in ref.py (tests/test_kernels.py):
#   hadamard.py      — blocked H_r (x) H_c rotation core (MXU matmuls)
#   lattice_quant.py — elementwise encode/decode streams
#   exchange.py      — fused rotated-space exchange (rotate+round+wrap /
#                      snap+inverse-rotate), batched over messages; the
#                      production path via repro.compression.pipeline
#   flash_attention.py — attention tile for the model substrate
#   ops.py           — public jit'd wrappers (interpret on CPU)
