"""Pallas TPU kernel: blocked Hadamard rotation.

TPU adaptation (DESIGN.md §3): the randomized Hadamard rotation used by the
lattice quantizer is the per-round compute hot-spot on the client/server
exchange path (two full passes over the model per round). A butterfly FWHT
is VPU-bound and strides badly through VMEM; instead we express the size-
(r·c) Hadamard as H_r ⊗ H_c and compute H_r @ X @ H_c per (r, c) block —
two 128×128-aligned MXU matmuls per block, VMEM-tiled with one block per
grid step.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.compression.rotation import hadamard_matrix


def _hadamard_kernel(x_ref, hr_ref, hc_ref, o_ref, *, scale: float):
    x = x_ref[0].astype(jnp.float32)
    y = jnp.dot(hr_ref[...], x, preferred_element_type=jnp.float32)
    y = jnp.dot(y, hc_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = y * scale


@partial(jax.jit, static_argnames=("interpret",))
def hadamard_blocks(x_blocks: jnp.ndarray, *, interpret: bool = True):
    """x_blocks: (n, r, c) fp32 -> (H_r X H_c)/sqrt(rc), blockwise.

    H is symmetric, so this is its own inverse-rotation core. Grid over
    blocks; per-step VMEM footprint = r*c + r*r + c*c floats (e.g. 192 KiB
    for 128x128) — well inside the ~16 MiB v5e VMEM budget.
    """
    n, r, c = x_blocks.shape
    hr = jnp.asarray(hadamard_matrix(r))
    hc = jnp.asarray(hadamard_matrix(c))
    scale = 1.0 / np.sqrt(r * c)
    return pl.pallas_call(
        partial(_hadamard_kernel, scale=scale),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, r, c), lambda i: (i, 0, 0)),
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((c, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r, c), jnp.float32),
        interpret=interpret,
    )(x_blocks.astype(jnp.float32), hr, hc)
