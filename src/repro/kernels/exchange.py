"""Pallas TPU kernels: fused rotated-space lattice exchange.

The per-round hot path of the quantized exchange is ``rotate -> stochastic
round -> wrap`` on the way out and ``snap -> inverse rotate`` on the way
back, over every sampled client's full model vector. The seed composition
materialized every intermediate (rotated coords, scaled coords, rounded
integers) in HBM; these kernels fuse each direction into one VMEM-resident
pass per (r, c) Hadamard block:

  * ``fused_rotate``  — sign flip + H_r @ X @ H_c / sqrt(rc) (fwd or inv)
  * ``fused_encode``  — rotate + floor(y/gamma + u) mod 2^b in one pass;
                        optionally also emits the rotated coords (the
                        rotated-space pipeline reuses them as the decode
                        reference, so the extra output replaces a whole
                        second rotation pass)
  * ``quantize_codes``— stochastic round + wrap of ALREADY-ROTATED coords:
                        the elementwise second half of ``fused_encode``. The
                        pipeline uses it to encode the server downlink from
                        its cached rotated coordinates, dropping the round's
                        forward-rotation budget from s+2 to s+1
  * ``snap_codes``    — positional snap only (stay in rotated space; the
                        pipeline averages rotated vectors and inverse-rotates
                        once at the end of the round)
  * ``fused_decode``  — rotate the reference + snap + inverse rotate: the
                        full ``Dec(ref, msg)`` in one pass (used by the
                        leaf-wise transport and the quantizer API)

**Sub-byte packing** (the ``lattice_packed`` codec): for ``bits`` in
{1, 2, 4} the encode-side kernels accept ``pack = 8 // bits`` and emit
``pack`` codes per byte — packed along the SUBLANE (r) axis of each
(r, c) Hadamard block, so the combine is a static reshape + shift-sum that
never crosses the 128-wide lane dimension — and the snap/decode kernels
unpack the same layout inline. The packed wire dtype is uint8 with
``d_pad // pack`` elements: at b=4 the codes tensor (what the
code_allgather transport moves over the interconnect) is exactly half the
unpacked uint8 bytes. ``pack=1`` (the default, and any ``bits >= 8``) is
bit-for-bit the historical unpacked path. :func:`pack_codes` /
:func:`unpack_codes` are the jnp reference implementations of the same
layout (used by the ``jnp`` backend and the per-message codec API).

All kernels run over a ``(m, nb)`` grid — ``m`` messages by ``nb`` Hadamard
blocks — with one (r, c) block per step; the two small Hadamard factors hit
the MXU directly. Batched operands broadcast along ``m`` through the block
index maps (no HBM materialization of the broadcast). Per-message scales
``gamma`` ride as lane-aligned (m, 128) rows so each grid step gets a
regular (1, 128) VMEM tile — direct loads from unblocked ``pl.ANY`` refs
do not lower on real TPUs.

**Per-message levels** (``GroupedLatticeCodec``): each quantizing kernel
optionally takes ``levels2`` — per-message wrap moduli (powers of two
<= ``2^bits``) riding as a second lane-aligned (m, 128) row operand, the
same layout as the γ rows. The kernel reads the modulus from the row
instead of the static ``2^bits`` constant, so one batched call mixes
heterogeneous client bit budgets. Sub-byte packing stays at the STATIC
``bits`` container width: every per-message modulus is <= ``2^bits`` by
construction, so each code fits the container; honest per-member wire
bits are the codec's accounting job (`GroupedLatticeCodec.bits_for`),
not the storage layout's.

On this CPU container everything runs with ``interpret=True``; the
``pallas`` backend flips that off on a real TPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.compression.rotation import (DEFAULT_BLOCK, _block_size, _factor,
                                        hadamard_matrix, pad_len)


LANE = 128


def _gamma_rows(gammas, m: int) -> jnp.ndarray:
    """Per-message scales as lane-aligned (m, LANE) rows (TPU-lowerable)."""
    g = jnp.asarray(gammas, jnp.float32).reshape(-1, 1)
    return jnp.broadcast_to(g, (m, LANE))


def block_geometry(d: int, block: int = DEFAULT_BLOCK):
    """(b, d_pad, r, c, nb) for a length-d vector under ``block``-blocking."""
    b = _block_size(d, block)
    d_pad = pad_len(d, block)
    r, c = _factor(b)
    return b, d_pad, r, c, d_pad // b


def _had(r: int, c: int):
    return jnp.asarray(hadamard_matrix(r)), jnp.asarray(hadamard_matrix(c))


def _check_pack(pack: int, bits: int, r: int):
    if pack == 1:
        return
    if pack * bits != 8:
        raise ValueError(f"pack={pack} requires pack*bits == 8 "
                         f"(got bits={bits})")
    if r % pack:
        raise ValueError(f"pack={pack} does not divide the Hadamard "
                         f"sublane factor r={r}; vector too small to pack")


def _pack_block(q, pack: int, bits: int):
    """(r, c) uint32 codes -> (r//pack, c) uint8, packed along sublanes."""
    r, c = q.shape
    qi = q.astype(jnp.uint32).reshape(r // pack, pack, c)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits)[None, :, None]
    return jnp.sum(qi << shifts, axis=1).astype(jnp.uint8)


def _unpack_block(p, pack: int, bits: int):
    """(r//pack, c) packed uint8 -> (r, c) uint32 codes."""
    rp, c = p.shape
    pi = p.astype(jnp.uint32)[:, None, :]
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits)[None, :, None]
    mask = jnp.uint32((1 << bits) - 1)
    return ((pi >> shifts) & mask).reshape(rp * pack, c)


def pack_codes(codes2: jnp.ndarray, *, bits: int,
               block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """(m, d_pad) codes -> (m, d_pad // (8//bits)) uint8, block-sublane
    packed — the ``lattice_packed`` wire layout (jnp reference)."""
    pack = 8 // bits
    m, d_pad = codes2.shape
    _, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    x = codes2.astype(jnp.uint32).reshape(m, nb, r // pack, pack, c)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits
              ).reshape(1, 1, 1, pack, 1)
    return jnp.sum(x << shifts, axis=3).astype(jnp.uint8).reshape(
        m, d_pad // pack)


def unpack_codes(packed2: jnp.ndarray, *, bits: int,
                 block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Inverse of :func:`pack_codes`: (m, d_pad//pack) uint8 -> (m, d_pad)
    uint32."""
    pack = 8 // bits
    m, dp = packed2.shape
    d_pad = dp * pack
    _, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    x = packed2.astype(jnp.uint32).reshape(m, nb, r // pack, 1, c)
    shifts = (jnp.arange(pack, dtype=jnp.uint32) * bits
              ).reshape(1, 1, 1, pack, 1)
    mask = jnp.uint32((1 << bits) - 1)
    return ((x >> shifts) & mask).reshape(m, d_pad)


def _row_spec(m: int, r: int, c: int):
    """BlockSpec for a (m_or_1, nb, r, c) operand broadcast along the grid's
    message axis when its leading dim is 1."""
    if m == 1:
        return pl.BlockSpec((1, 1, r, c), lambda i, j: (0, j, 0, 0))
    return pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0))


def _blk(x2: jnp.ndarray, nb: int, r: int, c: int):
    return x2.reshape(x2.shape[0], nb, r, c)


# ---------------------------------------------------------------------------
# kernel bodies
# ---------------------------------------------------------------------------

def _rotate_kernel(x_ref, s_ref, hr_ref, hc_ref, o_ref, *, scale: float,
                   inverse: bool):
    x = x_ref[0, 0].astype(jnp.float32)
    if not inverse:
        x = x * s_ref[0]
    y = jnp.dot(hr_ref[...], x, preferred_element_type=jnp.float32)
    y = jnp.dot(y, hc_ref[...], preferred_element_type=jnp.float32) * scale
    if inverse:
        y = y * s_ref[0]
    o_ref[0, 0] = y


def _bits_of(levels: int) -> int:
    return int(levels).bit_length() - 1


def _modulus(l_ref, levels: int):
    """Wrap/snap modulus: the per-message levels row when one rides along
    (grouped codecs), else the static 2^bits container."""
    return float(levels) if l_ref is None else l_ref[0, 0]


def _encode_kernel(x_ref, s_ref, u_ref, hr_ref, hc_ref, g_ref, l_ref, c_ref,
                   y_ref, *, scale: float, levels: int, want_rotated: bool,
                   pack: int = 1):
    x = x_ref[0, 0].astype(jnp.float32) * s_ref[0]
    y = jnp.dot(hr_ref[...], x, preferred_element_type=jnp.float32)
    y = jnp.dot(y, hc_ref[...], preferred_element_type=jnp.float32) * scale
    g = g_ref[0, 0]
    q = jnp.floor(y / g + u_ref[0, 0])
    q = jnp.mod(q, _modulus(l_ref, levels)).astype(jnp.uint32)
    c_ref[0, 0] = q if pack == 1 else _pack_block(q, pack, _bits_of(levels))
    if want_rotated:
        y_ref[0, 0] = y


def _quantize_kernel(y_ref, u_ref, g_ref, l_ref, c_ref, *, levels: int,
                     pack: int = 1):
    g = g_ref[0, 0]
    q = jnp.floor(y_ref[0, 0].astype(jnp.float32) / g + u_ref[0, 0])
    q = jnp.mod(q, _modulus(l_ref, levels)).astype(jnp.uint32)
    c_ref[0, 0] = q if pack == 1 else _pack_block(q, pack, _bits_of(levels))


def _snap_kernel(c_ref, w_ref, g_ref, l_ref, o_ref, *, levels: int,
                 pack: int = 1):
    g = g_ref[0, 0]
    c = c_ref[0, 0]
    if pack > 1:
        c = _unpack_block(c, pack, _bits_of(levels))
    c = c.astype(jnp.float32)
    lv = _modulus(l_ref, levels)
    q = c + lv * jnp.round((w_ref[0, 0] / g - c) / lv)
    o_ref[0, 0] = q * g


def _decode_kernel(c_ref, ref_ref, s_ref, hr_ref, hc_ref, g_ref, l_ref,
                   o_ref, *, scale: float, levels: int, pack: int = 1):
    s = s_ref[0]
    w = ref_ref[0, 0].astype(jnp.float32) * s
    w = jnp.dot(hr_ref[...], w, preferred_element_type=jnp.float32)
    w = jnp.dot(w, hc_ref[...], preferred_element_type=jnp.float32) * scale
    g = g_ref[0, 0]
    c = c_ref[0, 0]
    if pack > 1:
        c = _unpack_block(c, pack, _bits_of(levels))
    c = c.astype(jnp.float32)
    lv = _modulus(l_ref, levels)
    q = c + lv * jnp.round((w / g - c) / lv)
    x = jnp.dot(hr_ref[...], q * g, preferred_element_type=jnp.float32)
    x = jnp.dot(x, hc_ref[...], preferred_element_type=jnp.float32) * scale
    o_ref[0, 0] = x * s


# ---------------------------------------------------------------------------
# jit'd wrappers — all take (m, d_pad) message batches + (d_pad,) signs
# ---------------------------------------------------------------------------

def _levels_operand(levels2, m: int):
    """(specs, operands) for an optional per-message levels row — the same
    lane-aligned (m, LANE) layout the γ rows use."""
    if levels2 is None:
        return [], []
    return ([pl.BlockSpec((1, LANE), lambda i, j: (i, 0))],
            [_gamma_rows(levels2, m)])

@partial(jax.jit, static_argnames=("block", "inverse", "interpret"))
def fused_rotate(x2: jnp.ndarray, signs: jnp.ndarray, *,
                 block: int = DEFAULT_BLOCK, inverse: bool = False,
                 interpret: bool = True) -> jnp.ndarray:
    """Batched randomized-Hadamard rotation: (m, d_pad) -> (m, d_pad)."""
    m, d_pad = x2.shape
    b, _, r, c, nb = block_geometry(d_pad, block)
    hr, hc = _had(r, c)
    out = pl.pallas_call(
        partial(_rotate_kernel, scale=1.0 / np.sqrt(b), inverse=inverse),
        grid=(m, nb),
        in_specs=[
            pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, r, c), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((r, r), lambda i, j: (0, 0)),
            pl.BlockSpec((c, c), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb, r, c), jnp.float32),
        interpret=interpret,
    )(_blk(x2.astype(jnp.float32), nb, r, c), signs.reshape(nb, r, c), hr, hc)
    return out.reshape(m, d_pad)


@partial(jax.jit, static_argnames=("bits", "block", "want_rotated",
                                   "interpret", "pack"))
def fused_encode(x2: jnp.ndarray, signs: jnp.ndarray, u2: jnp.ndarray,
                 gammas: jnp.ndarray, *, bits: int = 8,
                 block: int = DEFAULT_BLOCK, want_rotated: bool = False,
                 interpret: bool = True, pack: int = 1, levels2=None):
    """Rotate + stochastic-round + wrap in one pass.

    x2: (m, d_pad) padded messages; u2: U(0,1) rounding noise, same shape;
    gammas: (m,) per-message scales; levels2: optional (m,) per-message
    wrap moduli (powers of two <= 2^bits) riding as a levels row. Returns
    codes (m, d_pad) uint32 — or, with ``pack = 8 // bits`` > 1,
    sub-byte-packed codes (m, d_pad // pack) uint8 combined inside the
    kernel — or (rotated, codes) when ``want_rotated`` (one extra
    VMEM->HBM store per block instead of a second full rotation pass
    later).
    """
    m, d_pad = x2.shape
    b, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    hr, hc = _had(r, c)
    rp = r // pack
    code_dt = jnp.uint8 if pack > 1 else jnp.uint32
    out_shape = [jax.ShapeDtypeStruct((m, nb, rp, c), code_dt)]
    out_specs = [pl.BlockSpec((1, 1, rp, c), lambda i, j: (i, j, 0, 0))]
    if want_rotated:
        out_shape.append(jax.ShapeDtypeStruct((m, nb, r, c), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1, r, c),
                                      lambda i, j: (i, j, 0, 0)))
    l_specs, l_ops = _levels_operand(levels2, m)
    has_levels = levels2 is not None

    def body(x_ref, s_ref, u_ref, hr_ref, hc_ref, g_ref, *rest):
        l_ref = rest[0] if has_levels else None
        outs = rest[1:] if has_levels else rest
        _encode_kernel(x_ref, s_ref, u_ref, hr_ref, hc_ref, g_ref, l_ref,
                       outs[0], outs[1] if want_rotated else None,
                       scale=1.0 / np.sqrt(b), levels=1 << bits,
                       want_rotated=want_rotated, pack=pack)

    res = pl.pallas_call(
        body,
        grid=(m, nb),
        in_specs=[
            pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, r, c), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((r, r), lambda i, j: (0, 0)),
            pl.BlockSpec((c, c), lambda i, j: (0, 0)),
            pl.BlockSpec((1, LANE), lambda i, j: (i, 0)),
        ] + l_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(_blk(x2.astype(jnp.float32), nb, r, c), signs.reshape(nb, r, c),
      _blk(u2.astype(jnp.float32), nb, r, c), hr, hc, _gamma_rows(gammas, m),
      *l_ops)
    codes = res[0].reshape(m, d_pad // pack)
    if want_rotated:
        return res[1].reshape(m, d_pad), codes
    return codes


@partial(jax.jit, static_argnames=("bits", "block", "interpret", "pack"))
def quantize_codes(y2: jnp.ndarray, u2: jnp.ndarray, gammas: jnp.ndarray, *,
                   bits: int = 8, block: int = DEFAULT_BLOCK,
                   interpret: bool = True, pack: int = 1,
                   levels2=None) -> jnp.ndarray:
    """Stochastic-round + wrap of already-rotated coordinates.

    y2: (m, d_pad) ROTATED messages; u2: U(0,1) rounding noise, same shape;
    gammas: (m,) per-message scales; levels2: optional (m,) per-message wrap
    moduli. Elementwise — no Hadamard factors touch the MXU, so encoding a
    cached rotated vector costs no rotation pass. Bit-identical to the
    quantize half of ``fused_encode`` (``pack`` included).
    """
    m, d_pad = y2.shape
    _, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    rp = r // pack
    code_dt = jnp.uint8 if pack > 1 else jnp.uint32
    l_specs, l_ops = _levels_operand(levels2, m)
    has_levels = levels2 is not None

    def body(y_ref, u_ref, g_ref, *rest):
        _quantize_kernel(y_ref, u_ref, g_ref,
                         rest[0] if has_levels else None, rest[-1],
                         levels=1 << bits, pack=pack)

    out = pl.pallas_call(
        body,
        grid=(m, nb),
        in_specs=[
            pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, LANE), lambda i, j: (i, 0)),
        ] + l_specs,
        out_specs=pl.BlockSpec((1, 1, rp, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb, rp, c), code_dt),
        interpret=interpret,
    )(_blk(y2.astype(jnp.float32), nb, r, c),
      _blk(u2.astype(jnp.float32), nb, r, c), _gamma_rows(gammas, m),
      *l_ops)
    return out.reshape(m, d_pad // pack)


@partial(jax.jit, static_argnames=("bits", "block", "interpret", "pack"))
def snap_codes(codes2: jnp.ndarray, wrot2: jnp.ndarray, gammas: jnp.ndarray,
               *, bits: int = 8, block: int = DEFAULT_BLOCK,
               interpret: bool = True, pack: int = 1,
               levels2=None) -> jnp.ndarray:
    """Positional snap in rotated space: gamma * (c + L round((w/g-c)/L)).

    codes2 (mc, d_pad // pack) and wrot2 (mw, d_pad) broadcast along the
    message axis (mc or mw may be 1); gammas (and the optional per-message
    ``levels2`` moduli) have the codes' batch size. With ``pack > 1`` the
    codes arrive sub-byte packed and are unpacked inline, inside the
    kernel.
    """
    mc, d_padp = codes2.shape
    d_pad = d_padp * pack
    mw = wrot2.shape[0]
    m = max(mc, mw)
    _, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    rp = r // pack
    code_dt = jnp.uint8 if pack > 1 else jnp.uint32
    l_specs, l_ops = _levels_operand(levels2, m)
    has_levels = levels2 is not None

    def body(c_ref, w_ref, g_ref, *rest):
        _snap_kernel(c_ref, w_ref, g_ref,
                     rest[0] if has_levels else None, rest[-1],
                     levels=1 << bits, pack=pack)

    out = pl.pallas_call(
        body,
        grid=(m, nb),
        in_specs=[
            _row_spec(mc, rp, c),
            _row_spec(mw, r, c),
            pl.BlockSpec((1, LANE), lambda i, j: (i, 0)),
        ] + l_specs,
        out_specs=pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb, r, c), jnp.float32),
        interpret=interpret,
    )(_blk(codes2.astype(code_dt), nb, rp, c),
      _blk(wrot2.astype(jnp.float32), nb, r, c), _gamma_rows(gammas, m),
      *l_ops)
    return out.reshape(m, d_pad)


@partial(jax.jit, static_argnames=("bits", "block", "interpret", "pack"))
def fused_decode(codes2: jnp.ndarray, ref2: jnp.ndarray, signs: jnp.ndarray,
                 gammas: jnp.ndarray, *, bits: int = 8,
                 block: int = DEFAULT_BLOCK,
                 interpret: bool = True, pack: int = 1,
                 levels2=None) -> jnp.ndarray:
    """Full positional decode: rotate ref + snap + inverse rotate, fused.

    codes2 (mc, d_pad // pack) vs references ref2 (mr, d_pad) in ORIGINAL
    space; broadcasts along the message axis; ``levels2`` optionally
    carries per-message snap moduli (the codes' batch size). Packed codes
    (``pack > 1``) are unpacked inline. Returns (max(mc, mr), d_pad) fp32
    in original coordinates (caller unpads with [:, :d]).
    """
    mc = codes2.shape[0]
    mr, d_pad = ref2.shape
    m = max(mc, mr)
    b, _, r, c, nb = block_geometry(d_pad, block)
    _check_pack(pack, bits, r)
    rp = r // pack
    code_dt = jnp.uint8 if pack > 1 else jnp.uint32
    hr, hc = _had(r, c)
    l_specs, l_ops = _levels_operand(levels2, m)
    has_levels = levels2 is not None

    def body(c_ref, ref_ref, s_ref, hr_ref, hc_ref, g_ref, *rest):
        _decode_kernel(c_ref, ref_ref, s_ref, hr_ref, hc_ref, g_ref,
                       rest[0] if has_levels else None, rest[-1],
                       scale=1.0 / np.sqrt(b), levels=1 << bits, pack=pack)

    out = pl.pallas_call(
        body,
        grid=(m, nb),
        in_specs=[
            _row_spec(mc, rp, c),
            _row_spec(mr, r, c),
            pl.BlockSpec((1, r, c), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((r, r), lambda i, j: (0, 0)),
            pl.BlockSpec((c, c), lambda i, j: (0, 0)),
            pl.BlockSpec((1, LANE), lambda i, j: (i, 0)),
        ] + l_specs,
        out_specs=pl.BlockSpec((1, 1, r, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, nb, r, c), jnp.float32),
        interpret=interpret,
    )(_blk(codes2.astype(code_dt), nb, rp, c),
      _blk(ref2.astype(jnp.float32), nb, r, c), signs.reshape(nb, r, c),
      hr, hc, _gamma_rows(gammas, m), *l_ops)
    return out.reshape(m, d_pad)
