"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.rotation import hadamard_matrix


def hadamard_ref(x_blocks: jnp.ndarray) -> jnp.ndarray:
    """x_blocks: (n, r, c) -> (H_r @ X @ H_c) / sqrt(r*c). H is symmetric."""
    n, r, c = x_blocks.shape
    hr = jnp.asarray(hadamard_matrix(r))
    hc = jnp.asarray(hadamard_matrix(c))
    scale = 1.0 / np.sqrt(r * c)
    return jnp.einsum("ij,bjk,kl->bil", hr, x_blocks.astype(jnp.float32),
                      hc) * scale


def lattice_encode_ref(y: jnp.ndarray, u: jnp.ndarray, gamma, bits: int):
    """y: rotated coords; u: U(0,1) rounding noise. codes in [0, 2^bits)."""
    levels = 1 << bits
    q = jnp.floor(y.astype(jnp.float32) / gamma + u)
    return jnp.mod(q, levels).astype(jnp.uint32)


def lattice_decode_ref(codes: jnp.ndarray, w: jnp.ndarray, gamma, bits: int):
    """w: rotated reference. Snap to the representative nearest w/gamma."""
    levels = 1 << bits
    c = codes.astype(jnp.float32)
    q = c + levels * jnp.round((w.astype(jnp.float32) / gamma - c) / levels)
    return q * gamma


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                        softcap: float = 0.0):
    """q: (b, tq, h, dh); k, v: (b, tk, kv, dh). GQA by head repetition."""
    b, tq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, dh).astype(jnp.float32)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    scores = scores / np.sqrt(dh)
    if softcap:
        scores = softcap * jnp.tanh(scores / softcap)
    tk = k.shape[1]
    qpos = jnp.arange(tq)[:, None]
    kpos = jnp.arange(tk)[None, :]
    mask = jnp.ones((tq, tk), bool)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(b, tq, h, dh).astype(q.dtype)
