"""Pallas TPU kernel: causal GQA flash attention (online softmax).

The prefill hot-spot for the 32k shapes. Tiling: grid (b·h, n_q_blocks,
n_kv_blocks) with the kv dimension innermost ('arbitrary' semantics); the
running max/denominator/accumulator live in VMEM scratch and persist across
kv steps. Per-step VMEM: bq·dh (q) + bk·dh (k,v) + bq·bk (scores) floats —
(128, 128, 512)-tiles ≈ 0.6 MiB, MXU-aligned.

Supports sliding-window and logit-softcap variants (gemma2/gemma3/llama4
schedules). GQA is handled in the k/v index_map: q-head ih reads kv-head
ih // group.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, softcap: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, dh)
    k = k_ref[0].astype(jnp.float32)          # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@partial(jax.jit,
         static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                          "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (b, tq, h, dh); k, v: (b, tk, kv, dh) with h % kv == 0."""
    b, tq, h, dh = q.shape
    tk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(block_q, tq)
    bk = min(block_k, tk)
    assert tq % bq == 0 and tk % bk == 0, (tq, bq, tk, bk)
    nq, nk = tq // bq, tk // bk
    scale = 1.0 / np.sqrt(dh)

    qh = jnp.moveaxis(q, 2, 1).reshape(b * h, tq, dh)
    kh = jnp.moveaxis(k, 2, 1).reshape(b * kvh, tk, dh)
    vh = jnp.moveaxis(v, 2, 1).reshape(b * kvh, tk, dh)

    def kv_index(ih, qi, ki):
        return (ih // h) * kvh + (ih % h) // g, ki, 0

    out = pl.pallas_call(
        partial(_flash_kernel, scale=scale, softcap=softcap, causal=causal,
                window=window, bq=bq, bk=bk, nk=nk),
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda ih, qi, ki: (ih, qi, 0)),
            pl.BlockSpec((1, bk, dh), kv_index),
            pl.BlockSpec((1, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda ih, qi, ki: (ih, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dh), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return jnp.moveaxis(out.reshape(b, h, tq, dh), 1, 2)
