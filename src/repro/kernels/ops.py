"""Public jit'd wrappers for the Pallas kernels.

On this CPU container the kernels run in ``interpret=True`` mode (the body
executes in Python for correctness validation); on a real TPU pass
``interpret=False``. The pure-jnp oracles live in ref.py and every kernel is
swept against them in tests/test_kernels.py.

``rotate_pallas`` is a drop-in for repro.compression.rotation.rotate with the
Hadamard core executed by the MXU kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.compression.rotation import (DEFAULT_BLOCK, _block_size, _factor,
                                        _signs, pad_len)
from repro.kernels.flash_attention import flash_attention  # noqa: F401
from repro.kernels.hadamard import hadamard_blocks
from repro.kernels.lattice_quant import lattice_decode, lattice_encode  # noqa: F401


def rotate_pallas(x: jnp.ndarray, key, block: int = DEFAULT_BLOCK,
                  inverse: bool = False, interpret: bool = True):
    """Randomized Hadamard rotation with the Pallas MXU core."""
    d = x.shape[0]
    b = _block_size(d, block)
    padded = pad_len(d, block)
    x = jnp.pad(x.astype(jnp.float32), (0, padded - d))
    s = _signs(key, padded)
    r, c = _factor(b)
    if not inverse:
        x = x * s
    y = hadamard_blocks(x.reshape(-1, r, c), interpret=interpret).reshape(-1)
    if inverse:
        y = y * s
    return y
