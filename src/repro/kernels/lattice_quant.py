"""Pallas TPU kernels: fused lattice-quantizer encode / decode.

encode: codes = floor(y/γ + u) mod 2^b       (stochastic round + wrap)
decode: x̂    = γ·(codes + 2^b·round((w/γ − codes)/2^b))   (positional snap)

Both are elementwise streams over the (padded) rotated vector: VMEM-tiled
(8, 128)-aligned rows, one tile per grid step. Fusing scale, round, wrap and
snap into one pass halves the HBM traffic versus the jnp composition (which
materializes y/γ and the rounded intermediate).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
SUB = 8
TILE = LANE * SUB * 8  # elements per grid step


def _encode_kernel(y_ref, u_ref, g_ref, o_ref, *, levels: int):
    g = g_ref[0]
    q = jnp.floor(y_ref[...] / g + u_ref[...])
    o_ref[...] = jnp.mod(q, float(levels)).astype(jnp.uint32)


def _decode_kernel(c_ref, w_ref, g_ref, o_ref, *, levels: int):
    g = g_ref[0]
    c = c_ref[...].astype(jnp.float32)
    q = c + levels * jnp.round((w_ref[...] / g - c) / levels)
    o_ref[...] = q * g


def _tiles(d: int):
    assert d % (SUB * LANE) == 0, d
    rows = d // LANE
    block_rows = min(rows, SUB * 8)
    while rows % block_rows:
        block_rows //= 2
    return rows, block_rows


@partial(jax.jit, static_argnames=("bits", "interpret"))
def lattice_encode(y: jnp.ndarray, u: jnp.ndarray, gamma, *, bits: int = 8,
                   interpret: bool = True):
    """y: rotated coords (d,), d % 1024 == 0; u: U(0,1) noise (d,)."""
    d = y.shape[0]
    rows, br = _tiles(d)
    y2 = y.reshape(rows, LANE).astype(jnp.float32)
    u2 = u.reshape(rows, LANE).astype(jnp.float32)
    g = jnp.asarray(gamma, jnp.float32).reshape(1)
    out = pl.pallas_call(
        partial(_encode_kernel, levels=1 << bits),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((br, LANE), lambda i: (i, 0)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.uint32),
        interpret=interpret,
    )(y2, u2, g)
    return out.reshape(d)


@partial(jax.jit, static_argnames=("bits", "interpret"))
def lattice_decode(codes: jnp.ndarray, w: jnp.ndarray, gamma, *,
                   bits: int = 8, interpret: bool = True):
    """codes: (d,) uint; w: rotated reference (d,)."""
    d = codes.shape[0]
    rows, br = _tiles(d)
    c2 = codes.reshape(rows, LANE).astype(jnp.uint32)
    w2 = w.reshape(rows, LANE).astype(jnp.float32)
    g = jnp.asarray(gamma, jnp.float32).reshape(1)
    out = pl.pallas_call(
        partial(_decode_kernel, levels=1 << bits),
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, LANE), lambda i: (i, 0)),
                  pl.BlockSpec((br, LANE), lambda i: (i, 0)),
                  pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(c2, w2, g)
    return out.reshape(d)
