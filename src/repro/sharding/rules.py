"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

A rule maps a logical axis name to a mesh axis (or a priority list of mesh
axes). ``pspec_for`` applies rules with a divisibility check — a dimension
that does not divide evenly by the mesh axis size is left replicated (e.g.
llama4's 40 q-heads over a 16-way model axis: the *flattened* q_flat=5120
dim shards instead, which is why projection weights use flattened head dims).
"""
from __future__ import annotations

from typing import Dict, Sequence, Union

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rule = Union[None, str, Sequence[str]]

# Tensor-parallel inside a replica; clients stacked over the data axis.
RULES_TP: Dict[str, Rule] = {
    "vocab": "model",
    "q_flat": "model",
    "kv_flat": "model",
    "mlp": "model",
    "expert_mlp": "model",
    "experts": None,
    "lora": None,
    "embed": None,
    "layers": None,
    "clients": "data",
    # activations / cache
    "batch": "data",
    "batch_local": None,   # per-client batch (client replicas own 'data')
    "kv_seq": "data",      # claimed only when 'data' is still free (batch=1)
    "kv_heads": "model",   # decode cache: kv heads over model when divisible
    "head_dim": "model",   # ...else head_dim (128 % 16 == 0 everywhere)
    "kv_lora": "model",    # MLA compressed cache dim
    "act_seq": None,
    "act_model": "model",
}

# Cohort mode for the giant architectures: one client per pod; parameters are
# additionally fully-sharded (FSDP) over the data axis on the embed dim.
RULES_FSDP: Dict[str, Rule] = dict(
    RULES_TP,
    embed="data",
    clients="pod",
    batch_local="data",    # the cohort's batch spreads over the data axis
)

# Expert-parallel variant (§Perf hillclimb): experts over the model axis,
# expert-FFN dim replicated.
RULES_EP: Dict[str, Rule] = dict(
    RULES_TP,
    experts="model",
    expert_mlp=None,
)


def rules_for_mode(mode: str) -> Dict[str, Rule]:
    return {"client_dp": RULES_TP, "cohort": RULES_FSDP, "ep": RULES_EP}[mode]


def pspec_for(shape, axes, rules: Dict[str, Rule], mesh: Mesh) -> P:
    """Build a PartitionSpec for one array, honoring divisibility and
    never assigning the same mesh axis twice."""
    used = set()
    out = []
    for dim, ax in zip(shape, axes):
        assign = None
        cands = rules.get(ax) if ax is not None else None
        if cands is not None:
            if isinstance(cands, str):
                cands = [cands]
            for cand in cands:
                if cand in used or cand not in mesh.shape:
                    continue
                if dim % mesh.shape[cand] == 0 and dim >= mesh.shape[cand]:
                    assign = cand
                    used.add(cand)
                    break
        out.append(assign)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(shape_tree, axes_tree, rules, mesh):
    """shape_tree: dict path->ShapeDtypeStruct; axes_tree: path->axes."""
    return {k: pspec_for(v.shape, axes_tree[k], rules, mesh)
            for k, v in shape_tree.items()}


def tree_shardings(shape_tree, axes_tree, rules, mesh):
    return {k: NamedSharding(mesh, s)
            for k, s in tree_pspecs(shape_tree, axes_tree, rules,
                                    mesh).items()}
