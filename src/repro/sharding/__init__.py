from repro.sharding.rules import (RULES_TP, RULES_FSDP, RULES_EP,  # noqa: F401
                                  pspec_for, tree_pspecs, tree_shardings,
                                  rules_for_mode)
