"""llava-next-34b [vlm] — anyres tiling; language backbone only.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000. The vision tower +
projector are STUBBED per the assignment: input_specs() supplies projected
patch embeddings (anyres: 5 tiles x 576 patches = 2880 image tokens).
[hf:llava-hf/llava-v1.6-mistral-7b-hf]
"""
from repro.configs.base import ATTN_FULL, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b",
        arch_type="vlm",
        source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
        d_ff=20_480, vocab_size=64_000,
        schedule=(LayerSpec(attn=ATTN_FULL),),
        frontend="vision",
        n_frontend_tokens=2880,  # anyres: 4 tiles + base, 576 patches each
        rope_theta=5_000_000.0,
        long_500k_ok=False,
        long_500k_note="skipped: pure full-attention VLM backbone "
                       "(see DESIGN.md).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, n_frontend_tokens=16,
        param_dtype="float32", dtype="float32",
    )
