"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.

26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000. [arXiv:2408.00118]
"""
from repro.configs.base import ATTN_FULL, ATTN_SLIDING, LayerSpec, ModelConfig

_LOCAL = LayerSpec(attn=ATTN_SLIDING, window=4096)
_GLOBAL = LayerSpec(attn=ATTN_FULL)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        arch_type="dense",
        source="arXiv:2408.00118",
        n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
        d_ff=9216, vocab_size=256_000,
        schedule=(_LOCAL, _GLOBAL),
        logit_softcap=30.0, attn_softcap=50.0,
        tie_embeddings=True,
        long_500k_ok=True,
        long_500k_note="half the layers are 4096-window local; global layers "
                       "keep the full cache (decode linear per token).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        schedule=(LayerSpec(attn=ATTN_SLIDING, window=64), _GLOBAL),
        param_dtype="float32", dtype="float32",
    )
