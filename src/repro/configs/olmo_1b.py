"""olmo-1b [dense] — non-parametric LayerNorm.

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304. [arXiv:2402.00838]

long_500k uses the sliding-window variant (window 8192) per the brief: the
source model is full-attention, so the variant is clearly flagged.
"""
from repro.configs.base import ATTN_FULL, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        arch_type="dense",
        source="arXiv:2402.00838",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
        d_ff=8192, vocab_size=50_304,
        schedule=(LayerSpec(attn=ATTN_FULL),),
        nonparametric_ln=True,
        tie_embeddings=True,
        long_500k_ok=True,
        long_ctx_window=8192,
        long_500k_note="run with the explicit sliding-window variant "
                       "(window 8192); the source model is full-attention.",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        param_dtype="float32", dtype="float32",
    )
