"""The paper's own experimental model family (App. A.3): a small MLP used by
the MNIST experiments. Used by the benchmark harness to reproduce the paper's
figures on synthetic classification data (the container is offline, so the
Gaussian-mixture task in repro.data stands in for MNIST/FMNIST/CIFAR/CelebA).
"""
from repro.configs.base import LayerSpec, ModelConfig


def config() -> ModelConfig:
    # (784, 32, 10) MLP analogue: d_model doubles as the hidden width.
    return ModelConfig(
        name="paper-mlp",
        arch_type="mlp",
        source="QuAFL paper App. A.3 (MNIST MLP 784-32-10)",
        n_layers=1, d_model=32, n_heads=1, n_kv_heads=1, head_dim=1,
        d_ff=32, vocab_size=10,
        schedule=(LayerSpec(),),
        param_dtype="float32", dtype="float32",
        notes="Consumed by repro.core baselines via repro.models.mlp, not the "
              "transformer stack.",
    )


def reduced() -> ModelConfig:
    return config()
