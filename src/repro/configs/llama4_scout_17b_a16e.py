"""llama4-scout-17b-a16e [moe] — MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts top-1
(+1 shared expert). iRoPE layout: 3 chunked-local-attention layers (8192
chunk) then 1 global NoPE layer. [hf:meta-llama/Llama-4-Scout-17B-16E]
"""
from repro.configs.base import (
    ATTN_CHUNKED, ATTN_FULL, LayerSpec, ModelConfig, MoEConfig)

_LOCAL = LayerSpec(attn=ATTN_CHUNKED, window=8192, mlp="moe")
_GLOBAL = LayerSpec(attn=ATTN_FULL, mlp="moe", use_rope=False)


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        arch_type="moe",
        source="hf:meta-llama/Llama-4-Scout-17B-16E",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
        d_ff=8192, vocab_size=202_048,
        schedule=(_LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        moe=MoEConfig(n_experts=16, top_k=1, d_ff_expert=8192,
                      n_shared=1, d_ff_shared=8192),
        rope_theta=500_000.0,
        long_500k_ok=True,
        long_500k_note="3/4 of layers are 8192-chunked local attention "
                       "(iRoPE); global NoPE layers decode against the full "
                       "cache (linear per decoded token).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        schedule=(LayerSpec(attn=ATTN_CHUNKED, window=64, mlp="moe"),
                  LayerSpec(attn=ATTN_FULL, mlp="moe", use_rope=False)),
        moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=256,
                      n_shared=1, d_ff_shared=256),
        param_dtype="float32", dtype="float32",
    )
