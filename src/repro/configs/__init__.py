"""Architecture registry.

Each assigned architecture lives in its own module exposing ``config()`` (the
exact assigned numbers) and ``reduced()`` (a <=2-layer, d_model<=512,
<=4-expert member of the same family for CPU smoke tests).

Select with ``--arch <id>`` anywhere in the launchers.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    ATTN_CHUNKED, ATTN_FULL, ATTN_MLA, ATTN_SLIDING, KIND_ATTN, KIND_MAMBA,
    FedConfig, LayerSpec, MambaConfig, MeshConfig, MLAConfig, ModelConfig,
    MoEConfig, ShapeConfig, SHAPES, TrainConfig,
)

# arch id -> module name
_ARCHS: Dict[str, str] = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "gemma2-2b": "gemma2_2b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "mamba2-370m": "mamba2_370m",
    "llava-next-34b": "llava_next_34b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "gemma3-12b": "gemma3_12b",
    "olmo-1b": "olmo_1b",
    "llama3.2-1b": "llama3_2_1b",
    # paper's own experimental models (MLP / residual CNN analogues)
    "paper-mlp": "paper_mlp",
}


def list_archs() -> List[str]:
    return list(_ARCHS)


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; choose from {list(_ARCHS)}")
    return importlib.import_module(f"repro.configs.{_ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).reduced()
