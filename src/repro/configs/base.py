"""Config dataclasses: architecture, shapes, mesh, federation.

Every assigned architecture gets one file in this package with a ``config()``
(full, exact assigned numbers) and a ``reduced()`` (<=2 layers, d_model<=512,
<=4 experts) used by the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Layer schedule
# ---------------------------------------------------------------------------

# attention kinds
ATTN_FULL = "full"
ATTN_SLIDING = "sliding"
ATTN_CHUNKED = "chunked"   # llama4-style local chunked attention
ATTN_MLA = "mla"           # deepseek multi-head latent attention
KIND_ATTN = "attn"
KIND_MAMBA = "mamba"


@dataclass(frozen=True)
class LayerSpec:
    """One layer inside the repeating period of the network."""
    kind: str = KIND_ATTN          # 'attn' | 'mamba'
    attn: str = ATTN_FULL          # attention flavour (if kind == 'attn')
    window: int = 0                # sliding-window / chunk size (0 = n/a)
    mlp: str = "dense"             # 'dense' | 'moe'
    use_rope: bool = True          # NoPE layers (llama4 global) set False
    rope_theta: float = 0.0        # per-layer override (0 = model default)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0           # defaults to d_ff_expert * n_shared if 0
    router_aux_coef: float = 0.01
    impl: str = "ragged"           # 'ragged' (lax.ragged_dot) | 'dense' (one-hot)
    capacity_factor: float = 1.25  # only for the dense impl


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk: int = 256               # SSD chunk length
    ngroups: int = 1


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                 # dense | moe | ssm | hybrid | vlm | audio
    source: str                    # citation for the numbers
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer layout: n_layers == len(prefix) + n_periods * len(schedule)
    schedule: Tuple[LayerSpec, ...] = (LayerSpec(),)
    prefix: Tuple[LayerSpec, ...] = ()
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    mla: Optional[MLAConfig] = None
    # misc architectural knobs
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0
    qk_norm: bool = False
    nonparametric_ln: bool = False # OLMo-style LN without learnable affine
    tie_embeddings: bool = False
    # encoder-decoder
    encdec: bool = False
    n_enc_layers: int = 0
    # modality frontend stub ('' | 'vision' | 'audio'); stubbed embeddings are
    # provided by input_specs() per the assignment carve-out.
    frontend: str = ""
    n_frontend_tokens: int = 0     # image/audio tokens included in the seq
    # long-context support
    long_500k_ok: bool = False
    long_ctx_window: int = 0       # >0: sliding-window variant used for long_500k
    long_500k_note: str = ""
    # dtypes
    dtype: str = "bfloat16"        # activation / compute dtype
    param_dtype: str = "float32"
    notes: str = ""

    @property
    def n_periods(self) -> int:
        body = self.n_layers - len(self.prefix)
        assert body % len(self.schedule) == 0, (
            f"{self.name}: {self.n_layers} layers, prefix {len(self.prefix)}, "
            f"period {len(self.schedule)} does not divide")
        return body // len(self.schedule)

    def replace(self, **kw) -> ModelConfig:
        return dataclasses.replace(self, **kw)

    def with_long_variant(self) -> ModelConfig:
        """Sliding-window variant used only for the long_500k shape."""
        if self.long_ctx_window <= 0:
            return self
        sched = tuple(
            dataclasses.replace(s, attn=ATTN_SLIDING, window=self.long_ctx_window)
            if s.kind == KIND_ATTN and s.attn == ATTN_FULL else s
            for s in self.schedule)
        pre = tuple(
            dataclasses.replace(s, attn=ATTN_SLIDING, window=self.long_ctx_window)
            if s.kind == KIND_ATTN and s.attn == ATTN_FULL else s
            for s in self.prefix)
        return self.replace(schedule=sched, prefix=pre)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


# ---------------------------------------------------------------------------
# Federation (QuAFL) configuration — paper Alg. 1 knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FedConfig:
    n_clients: int = 16            # n in the paper
    s: int = 16                    # sampled clients per round
    local_steps: int = 4           # K
    lr: float = 0.1                # eta (client SGD step)
    # paper App. A: 'Unless otherwise noted, we employ the unweighted version'
    weighted: bool = False         # eta_i = H_min / H_i dampening
    quantizer: str = "lattice"     # 'lattice' | 'qsgd' | 'none'
    bits: int = 8
    # per-direction codec specs (repro.compression.codecs registry names,
    # e.g. 'lattice_packed', 'scalar:bits=4', 'topk_ef:frac=0.01'); ""
    # derives the historical scheme from `quantizer` + `bits` — every
    # registry algorithm resolves its uplink/downlink compression from
    # these unless given explicit uplink=/downlink= kwargs
    codec_up: str = ""
    codec_down: str = ""
    # compression-pipeline kernel backend (repro.compression.pipeline):
    #  'jnp'              — pure-jnp composition (CPU CI default)
    #  'pallas_interpret' — Pallas kernels through the interpreter (CPU
    #                       validation of the exact TPU code path)
    #  'pallas'           — compiled Pallas kernels (real TPU)
    kernel_backend: str = "jnp"
    # client speed model (App. A timing experiments): step time ~ Exp(lam)
    slow_frac: float = 0.3
    lam_fast: float = 0.5
    lam_slow: float = 0.125
    swt: float = 10.0              # server waiting time between calls
    sit: float = 1.0               # server interaction time
    # client participation/availability spec (repro.fed.population
    # registry: 'uniform' | 'gamma_straggler[:strength=a]' |
    # 'cyclic:period=P,phase_groups=G'); "" = uniform — the paper's s-of-n
    # sampling without replacement, preserved draw-for-draw
    participation: str = ""
    # distribution of H_i^t used inside the SPMD train_step
    # 'binomial' -> H ~ Binomial(K, p_i); faithful "partial progress" draws
    h_dist: str = "binomial"
    seed: int = 0
    # aggregation transport on the mesh:
    #  'dequant_psum'  — faithful: decode locally then all-reduce fp32
    #  'code_allgather'— beyond-paper: all-gather packed codes, decode after
    #  'shard_local' / 'shard_local_codes' / 'shard_local_rs' — the whole
    #  exchange inside one shard_map (repro.core.exchange_local), client
    #  sum carried by the named repro.compression.transports strategy
    #  (fp32 psum / packed-code all-gather / fused reduce_scatter with the
    #  scatter-resident coded re-gather)
    transport: str = "dequant_psum"


@dataclass(frozen=True)
class MeshConfig:
    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig = None
    fed: FedConfig = FedConfig()
    mesh: MeshConfig = MeshConfig()
    seq_len: int = 4096
    global_batch: int = 256
    steps: int = 100
    eval_every: int = 20
    remat: bool = True
    seq_shard_residual: bool = False  # Megatron-style sequence sharding of the residual stream
    log_every: int = 10
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
