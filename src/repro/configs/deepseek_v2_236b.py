"""deepseek-v2-236b [moe] — MLA (kv_lora=512), 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff(expert)=1536 vocab=102400. First layer is a dense
MLP (d_ff=12288), the rest are MoE. [arXiv:2405.04434]
"""
from repro.configs.base import ATTN_MLA, LayerSpec, MLAConfig, ModelConfig, MoEConfig

_MLA_DENSE = LayerSpec(attn=ATTN_MLA, mlp="dense")
_MLA_MOE = LayerSpec(attn=ATTN_MLA, mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        arch_type="moe",
        source="arXiv:2405.04434",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
        d_ff=12_288, vocab_size=102_400,
        prefix=(_MLA_DENSE,),
        schedule=(_MLA_MOE,),
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                      qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=160, top_k=6, d_ff_expert=1536,
                      n_shared=2, d_ff_shared=3072),
        long_500k_ok=False,
        long_500k_note="skipped: pure full MLA attention, no sliding-window "
                       "variant in the source model (see DESIGN.md).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512,
        prefix=(_MLA_DENSE,), schedule=(_MLA_MOE,),
        mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                      qk_nope_dim=16, qk_rope_dim=16, v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                      n_shared=1, d_ff_shared=64),
        param_dtype="float32", dtype="float32",
    )
