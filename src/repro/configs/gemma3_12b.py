"""gemma3-12b [dense] — 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.
[hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ATTN_FULL, ATTN_SLIDING, LayerSpec, ModelConfig

# gemma3 dual RoPE: local layers theta=10k, global layers theta=1M
_LOCAL = LayerSpec(attn=ATTN_SLIDING, window=1024, rope_theta=10_000.0)
_GLOBAL = LayerSpec(attn=ATTN_FULL, rope_theta=1_000_000.0)


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        arch_type="dense",
        source="hf:google/gemma-3-1b-pt",
        n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
        d_ff=15_360, vocab_size=262_144,
        schedule=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
        qk_norm=True,
        tie_embeddings=True,
        rope_theta=1_000_000.0,
        long_500k_ok=True,
        long_500k_note="5/6 of layers are 1024-window local; global layers "
                       "keep the full cache (decode linear per token).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        schedule=(LayerSpec(attn=ATTN_SLIDING, window=64), _GLOBAL),
        param_dtype="float32", dtype="float32",
    )
