"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2 on
every other layer. Period of 8 layers with one attention layer (index 3).
[arXiv:2403.19887]
"""
from repro.configs.base import (
    ATTN_FULL, KIND_MAMBA, LayerSpec, MambaConfig, ModelConfig, MoEConfig)

_M_D = LayerSpec(kind=KIND_MAMBA, mlp="dense")
_M_E = LayerSpec(kind=KIND_MAMBA, mlp="moe")
_A_E = LayerSpec(kind="attn", attn=ATTN_FULL, mlp="moe")


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b",
        arch_type="hybrid",
        source="arXiv:2403.19887",
        n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=24_576, vocab_size=65_536,
        # 1 attention : 7 mamba per period; MoE every other layer
        schedule=(_M_D, _M_E, _M_D, _A_E, _M_D, _M_E, _M_D, _M_E),
        mamba=MambaConfig(d_state=128, expand=2, head_dim=64,
                          conv_width=4, chunk=256),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24_576),
        long_500k_ok=True,
        long_500k_note="7/8 of layers are Mamba (constant state); the 9 "
                       "attention layers decode against the cache "
                       "(linear per decoded token).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512,
        schedule=(LayerSpec(kind=KIND_MAMBA, mlp="dense"),
                  LayerSpec(kind="attn", attn=ATTN_FULL, mlp="moe")),
        mamba=MambaConfig(d_state=16, expand=2, head_dim=32,
                          conv_width=4, chunk=32),
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128),
        param_dtype="float32", dtype="float32",
    )
