"""llama3.2-1b [dense] — small llama3.

16L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B]

long_500k uses the sliding-window variant (window 8192) per the brief.
"""
from repro.configs.base import ATTN_FULL, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        arch_type="dense",
        source="hf:meta-llama/Llama-3.2-1B",
        n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
        d_ff=8192, vocab_size=128_256,
        schedule=(LayerSpec(attn=ATTN_FULL),),
        tie_embeddings=True,
        rope_theta=500_000.0,
        long_500k_ok=True,
        long_ctx_window=8192,
        long_500k_note="run with the explicit sliding-window variant "
                       "(window 8192); the source model is full-attention.",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=512,
        param_dtype="float32", dtype="float32",
    )
