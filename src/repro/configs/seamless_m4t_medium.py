"""seamless-m4t-medium [audio] — encoder-decoder, multimodal.

12L(enc)+12L(dec) d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=256206. The
speech frontend (mel-spectrogram + conv feature extractor) is STUBBED per the
assignment: input_specs() supplies frame embeddings. [arXiv:2308.11596]
"""
from repro.configs.base import ATTN_FULL, LayerSpec, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        arch_type="audio",
        source="arXiv:2308.11596",
        n_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, head_dim=64,
        d_ff=4096, vocab_size=256_206,
        schedule=(LayerSpec(attn=ATTN_FULL),),
        encdec=True, n_enc_layers=12,
        frontend="audio",
        long_500k_ok=False,
        long_500k_note="skipped: enc-dec speech model; a 500k-token decode is "
                       "outside the model's operating regime (see DESIGN.md).",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, n_enc_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512,
        param_dtype="float32", dtype="float32",
    )
