"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128. [arXiv:2405.21060]
"""
from repro.configs.base import KIND_MAMBA, LayerSpec, MambaConfig, ModelConfig

_MAMBA = LayerSpec(kind=KIND_MAMBA, mlp="none")


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m",
        arch_type="ssm",
        source="arXiv:2405.21060",
        n_layers=48, d_model=1024, n_heads=32, n_kv_heads=0, head_dim=64,
        d_ff=0, vocab_size=50_280,
        schedule=(_MAMBA,),
        mamba=MambaConfig(d_state=128, expand=2, head_dim=64,
                          conv_width=4, chunk=256),
        tie_embeddings=True,
        long_500k_ok=True,
        long_500k_note="attention-free; decode carries a constant-size SSM "
                       "state, no KV cache.",
    )


def reduced() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=4, vocab_size=512,
        mamba=MambaConfig(d_state=16, expand=2, head_dim=64,
                          conv_width=4, chunk=32),
        param_dtype="float32", dtype="float32",
    )
