"""Checkpointing for flat-dict pytrees (npz payload + JSON manifest).

Layout: <dir>/step_<n>/arrays.npz + manifest.json. Restore is
shape/dtype-checked against the manifest and (optionally) a template tree.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}::"))
    elif hasattr(tree, "_fields"):  # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}::"))
    else:
        out[prefix.rstrip(":")] = np.asarray(tree)
    return out


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[Dict] = None) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in flat.items()},
        "extra": extra or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)$", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template: Any) -> Any:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    def rebuild(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: rebuild(v, f"{prefix}{k}::") for k, v in tree.items()}
        if hasattr(tree, "_fields"):
            return type(tree)(**{k: rebuild(getattr(tree, k), f"{prefix}{k}::")
                                 for k in tree._fields})
        key = prefix.rstrip(":")
        arr = data[key]
        want = manifest["arrays"][key]
        assert list(arr.shape) == want["shape"], key
        return jax.numpy.asarray(arr)

    return rebuild(template)
