"""Synthetic federated data pipeline.

The container is offline, so MNIST/FMNIST/CIFAR/CelebA are replaced by a
Gaussian-mixture classification task with the same *federation structure* as
the paper's LEAF setup: a fixed random split (i.i.d. experiments) or a
by-class split where each client holds a non-overlapping subset of classes
(the paper's 'pure non-i.i.d.' CelebA setting).

For the LM architectures we generate per-client token streams from
client-specific Zipf distributions over the vocabulary (a controllable
non-iid knob: each client permutes the vocab differently).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# classification task (paper figures)
# ---------------------------------------------------------------------------

def gaussian_mixture(key, n_samples: int, d: int = 32, n_classes: int = 10,
                     sep: float = 3.0) -> Dict[str, jnp.ndarray]:
    kmu, kx, ky = jax.random.split(key, 3)
    mus = jax.random.normal(kmu, (n_classes, d)) * sep / np.sqrt(d)
    y = jax.random.randint(ky, (n_samples,), 0, n_classes)
    x = mus[y] + jax.random.normal(kx, (n_samples, d))
    return {"x": x, "y": y}


def partition_iid(key, data: Dict[str, jnp.ndarray], n_clients: int):
    """Fixed random split — each client gets a 1/n partition (paper §4)."""
    n = data["y"].shape[0]
    m = n // n_clients
    perm = jax.random.permutation(key, n)[: m * n_clients]
    idx = perm.reshape(n_clients, m)
    return {k: v[idx] for k, v in data.items()}  # leaves: (n_clients, m, ...)


def partition_by_class(key, data: Dict[str, jnp.ndarray], n_clients: int,
                       n_classes: int):
    """Pure non-i.i.d.: samples split across classes so each client receives
    a non-overlapping subset of classes (paper's CelebA setting)."""
    y = np.asarray(data["y"])
    order = np.argsort(y, kind="stable")
    n = len(order)
    m = n // n_clients
    idx = np.stack([order[i * m:(i + 1) * m] for i in range(n_clients)])
    # deterministic client shuffle so class blocks map to clients randomly
    perm = np.asarray(jax.random.permutation(key, n_clients))
    idx = idx[perm]
    return {k: v[jnp.asarray(idx)] for k, v in data.items()}


def make_federated_classification(seed: int, n_clients: int,
                                  samples_per_client: int = 256, d: int = 32,
                                  n_classes: int = 10, iid: bool = True,
                                  test_samples: int = 1024):
    key = jax.random.PRNGKey(seed)
    ktr, kte, kp = jax.random.split(key, 3)
    train = gaussian_mixture(ktr, n_clients * samples_per_client, d, n_classes)
    # validation drawn from the SAME mixture (class means shared)
    kmu, kx, ky = jax.random.split(ktr, 3)  # reuse means: regenerate directly
    test = gaussian_mixture(ktr, test_samples, d, n_classes)
    part = (partition_iid(kp, train, n_clients) if iid
            else partition_by_class(kp, train, n_clients, n_classes))
    return part, test


def client_batch(key, client_data, batch: int):
    """Sample a minibatch from one client's partition {'x': (m,d), 'y': (m,)}."""
    m = client_data["y"].shape[0]
    idx = jax.random.randint(key, (batch,), 0, m)
    return {k: v[idx] for k, v in client_data.items()}


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

def lm_token_stream(key, batch: int, seq_len: int, vocab: int,
                    client_id=0, zipf_a: float = 1.2) -> jnp.ndarray:
    """Zipf-ish token sampling with a per-client vocab permutation (non-iid).

    Pure-JAX (usable inside jit): inverse-CDF sampling of p(r) ∝ (r+1)^-a,
    then a client-specific pseudo-permutation token' = (token * prime_c +
    client_id) mod vocab.
    """
    ranks = jnp.arange(vocab, dtype=jnp.float32)
    w = (ranks + 1.0) ** (-zipf_a)
    cdf = jnp.cumsum(w) / jnp.sum(w)
    u = jax.random.uniform(key, (batch, seq_len))
    tok = jnp.searchsorted(cdf, u).astype(jnp.int32)
    prime = 1_000_003 % vocab
    tok = jnp.mod(tok * (prime + 2 * client_id + 1) + client_id * 7919, vocab)
    return tok


def make_federated_tokens(seed: int, n_clients: int, batch: int,
                          seq_len: int, vocab: int, noniid: bool = True):
    """(n_clients, batch, seq_len) int32 token batches (one round's data)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), n_clients)
    outs = [lm_token_stream(keys[i], batch, seq_len, vocab,
                            client_id=(i if noniid else 0))
            for i in range(n_clients)]
    return jnp.stack(outs)


def federated_token_task(seed: int, n_clients: int, pool: int, batch: int,
                         seq_len: int, vocab: int):
    """An LM task in the shape the FedAlgorithm protocol consumes: a
    per-client token pool + a minibatch sampler.

    Returns ``(data, batch_fn)`` where ``data`` is
    ``{"tokens": (n_clients, pool, seq_len)}`` and ``batch_fn(client_data,
    key)`` draws ``batch`` rows from one client's pool. Shared by the
    registry entry points (``launch/train.py --algo``,
    ``launch/serve.py --from-algo``).
    """
    data = {"tokens": make_federated_tokens(seed, n_clients, pool, seq_len,
                                            vocab)}

    def batch_fn(client_data, key):
        idx = jax.random.randint(key, (batch,), 0, pool)
        return {"tokens": client_data["tokens"][idx]}

    return data, batch_fn
