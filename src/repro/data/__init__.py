from repro.data.synthetic import (gaussian_mixture, lm_token_stream,  # noqa: F401
                                  make_federated_classification,
                                  make_federated_tokens, partition_iid,
                                  partition_by_class)
