"""Small pytree utilities used across the framework."""
from __future__ import annotations

import zlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np


def tree_size(tree: Any) -> int:
    """Total number of scalar parameters in a pytree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: Any) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_flatten_vector(tree: Any) -> jnp.ndarray:
    """Concatenate all leaves into one flat fp32 vector (QuAFL operates on R^d)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(x).astype(jnp.float32) for x in leaves])


def tree_unflatten_vector(tree_like: Any, vec: jnp.ndarray) -> Any:
    """Inverse of tree_flatten_vector relative to a template pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    out, off = [], 0
    for leaf in leaves:
        n = int(np.prod(leaf.shape))
        out.append(jnp.reshape(vec[off:off + n], leaf.shape).astype(leaf.dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_map(fn: Callable, *trees: Any) -> Any:
    return jax.tree_util.tree_map(fn, *trees)


def tree_add(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a: Any, b: Any) -> Any:
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(a: Any, c) -> Any:
    return jax.tree_util.tree_map(lambda x: x * c, a)


def tree_axpy(alpha, x: Any, y: Any) -> Any:
    """alpha * x + y, leafwise."""
    return jax.tree_util.tree_map(lambda xi, yi: alpha * xi + yi, x, y)


def tree_dot(a: Any, b: Any):
    parts = jax.tree_util.tree_map(
        lambda x, y: jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32)), a, b)
    return sum(jax.tree_util.tree_leaves(parts))


def tree_norm(a: Any):
    return jnp.sqrt(tree_dot(a, a))


def tree_zeros_like(a: Any) -> Any:
    return jax.tree_util.tree_map(jnp.zeros_like, a)


def tree_cast(a: Any, dtype) -> Any:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), a)


def path_str(path) -> str:
    """Render a jax KeyPath as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def fold_in_str(key: jax.Array, s: str) -> jax.Array:
    """Derive a sub-key deterministically from a string path."""
    return jax.random.fold_in(key, zlib.crc32(s.encode()) & 0x7FFFFFFF)
