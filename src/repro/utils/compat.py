"""JAX version compatibility shims.

The repo targets the current jax API (``jax.shard_map``, ``jax.make_mesh``
with ``axis_types``); the pinned container ships an older jax where those
spellings live under ``jax.experimental`` or lack keywords. Every call site
goes through this module so the rest of the codebase reads like modern jax.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType as _AxisType
except ImportError:  # pragma: no cover - depends on container jax
    _AxisType = None


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with Auto axis types where supported."""
    if _AxisType is not None:
        return jax.make_mesh(axis_shapes, axis_names,
                             axis_types=(_AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` (new) or ``jax.experimental.shard_map`` (old).

    ``check_vma`` maps onto the old API's ``check_rep``; both default off —
    the exchange/MoE bodies use collectives whose replication the checker
    cannot prove.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma)
