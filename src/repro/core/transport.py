"""Leaf-wise codec transport helpers for the DISTRIBUTED QuAFL train step.

The simulation core (repro.core.quafl) works on one flat vector; on a mesh
we encode per parameter leaf instead (each leaf flattens to its own vector,
rotation blocks never cross leaves). Algebraically this is still a valid
instance of the blockwise lattice quantizer — the rotation is block-diagonal
either way — and it keeps every encode/decode local to the shards that own
the leaf. The helpers are CODEC-AGNOSTIC: any
:mod:`repro.compression.codecs` object (or legacy quantizer) with
``encode(key, x, hint) / decode(key, msg, ref) / message_bits(d)`` rides
them, and messages are opaque pytrees.

The aggregation strategies themselves (fp32 psum vs. packed-code
all-gather vs. the reduce-scatter fusion) are the pluggable
:class:`repro.compression.transports.Transport` registry; the vmap-level
legacy compositions (dequant_psum / code_allgather) live in
``repro.launch.steps`` and the shard_map family in
``repro.core.exchange_local``.

The per-leaf encode/decode math runs through the compression-pipeline
backend selected by ``FedConfig.kernel_backend`` (lattice codecs delegate
to repro.compression.pipeline): each Enc is one fused rotate+round+wrap
pass and each Dec one fused rotate-ref+snap+inverse-rotate pass — no
materialized rotation intermediates. The fully rotated-space restructuring
(one rotation per vector per ROUND) lives in repro.core.exchange_local for
the shard-local transports and repro.compression.pipeline.quafl_round for
the flat simulator.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.compression.lattice import LatticeMsg
from repro.utils.tree import fold_in_str


def leaf_dist(a: Dict[str, Any], b: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
    """Per-leaf L2 distance between two flat-dict trees."""
    return {k: jnp.linalg.norm((a[k] - b[k]).astype(jnp.float32).ravel())
            for k in a}


def tree_encode(quant, key, tree: Dict[str, Any],
                hints: Dict[str, jnp.ndarray]) -> Dict[str, LatticeMsg]:
    out = {}
    for k, v in tree.items():
        out[k] = quant.encode(fold_in_str(key, k),
                              v.astype(jnp.float32).ravel(), hints[k] + 1e-12)
    return out


def tree_decode(quant, key, msgs: Dict[str, LatticeMsg],
                ref: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
    out = {}
    for k, m in msgs.items():
        flat = quant.decode(fold_in_str(key, k), m,
                            ref[k].astype(jnp.float32).ravel())
        out[k] = flat.reshape(ref[k].shape).astype(ref[k].dtype)
    return out


def tree_bits(quant, tree: Dict[str, Any]) -> int:
    import numpy as np
    return int(sum(quant.message_bits(int(np.prod(v.shape)))
                   for v in tree.values()))
