"""QuAFL — paper Algorithm 1, as a jit-able JAX round function.

The optimization state is kept as FLAT fp32 vectors (the paper's model is
x ∈ R^d): ``server`` (X_t) plus a :class:`repro.fed.population.Population`
store holding every per-client row — X^i models stacked (n, d), speeds λ,
last-interaction times, codec/EF residuals. Rounds reach the store only
through an O(s·d) gather/scatter of the sampled clients' rows, and WHO is
sampled is a first-class ``Participation`` spec (``uniform`` — the paper's
draw — ``gamma_straggler``, ``cyclic:period=P,phase_groups=G``), so the
population size n is a spec, not a hot-path cost. The loss is evaluated by
unflattening against a template pytree, so any model (the MLP family from
the paper's experiments or a transformer from the assigned zoo) plugs in
through ``loss_fn(params_pytree, batch)``.

Faithfulness notes:
 * Per App. B.1, local steps of unsampled clients have no observable effect,
   so they are computed lazily at poll time: on contact, client i draws
   H_i^t = min(K, Poisson(λ_i · elapsed_i)) — the number of Exp(λ_i)-duration
   steps it would have completed since its last interaction — and replays
   exactly that many SGD steps (masked lax.scan). H may be 0: the client is
   polled mid-flight with no progress, and still participates (paper §2.2).
   The speed model and the lazy draw live in ``repro.fed.clock`` (shared
   with every baseline so the comparison runs under ONE clock).
 * η_i = H_min/H_i dampening uses the EXPECTED speeds (weighted variant);
   the unweighted variant (paper App. A experiments) sets η_i = 1.
 * Both directions are quantized with the position-aware lattice quantizer.
   The server's Enc(X_t) is decoded by each sampled client against its own
   current model; the clients' Enc(Y^i) are decoded by the server against
   X_t (pseudocode lines 4–7).
 * Averaging: X_{t+1} = (X_t + Σ Q(Y^i)) / (s+1);
   X^i ← Q(X_t)/(s+1) + s·Y^i/(s+1) — preserves the model mean μ_t up to
   gradient and quantization noise (the paper's potential argument).

Perf: with ``quantizer="lattice"`` the whole exchange runs through the
rotated-space compression pipeline (repro.compression.pipeline): one shared
per-round rotation key, all encode/decode/averaging in rotated coordinates,
exactly s+1 forward + s+1 inverse full-model rotations per round (the seed
composition spent ~5s+1; the downlink Enc(X_t) is an elementwise quantize of
the cached rotated server). ``FedConfig.kernel_backend`` selects the
jnp / Pallas-interpret / Pallas implementation of the fused kernels;
``exchange_impl="reference"`` keeps the per-message materialize-everything
oracle for equivalence testing.

This class implements the :class:`repro.fed.FedAlgorithm` protocol
(``init / round / eval_params``) and emits the standardized metrics schema
(``sim_time``, ``bits_up``, ``bits_down``, ``h_steps_mean``, ``quant_err``,
...); select it by name via ``repro.fed.make_algorithm("quafl", ...)``.

Compression is COMPOSABLE (:mod:`repro.compression.codecs`): ``uplink=`` /
``downlink=`` codec specs (or ``FedConfig.codec_up`` / ``codec_down``)
select the per-direction scheme by name — lattice-family codecs (including
sub-byte ``lattice_packed`` wires and per-client heterogeneous
``{"fast": ..., "slow": ...}`` bit budgets) keep riding the fused
rotated-space pipeline; any other codec runs the per-message composition.
``bits_up`` / ``bits_down`` are computed by the codecs' wire accounting.
Error-feedback residuals assume a ZERO decode reference, which QuAFL's
model-vs-server uplink does not provide — stateful codecs therefore run
their stateless encode here (``QuaflState.codec_up_state`` stays empty
unless a codec declares itself reference-agnostic); the delta-style
uplinks (``fedbuff``, ``compressed_fedavg``) are where EF threads.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.codecs import (GroupedLatticeCodec,
                                      init_client_states, is_lattice_family,
                                      resolve_codec)
from repro.compression.lattice import make_quantizer
from repro.compression.pipeline import ExchangePipeline
from repro.configs.base import FedConfig
# canonical home is repro.fed.clock; re-exported here for compatibility
from repro.fed.clock import (client_speeds, expected_steps,  # noqa: F401
                             lazy_h_steps, sample_clients, speeds_for)
from repro.fed.population import (Population, build_population, gather_rows,
                                  resolve_participation, scatter_rows,
                                  shard_population, with_rows)
from repro.utils.tree import (tree_flatten_vector, tree_unflatten_vector)


class QuaflState(NamedTuple):
    """Server scalars + the :class:`Population` store of per-client rows.

    Per-client state (client models X^i, last-interaction times, codec/EF
    residuals, speeds) lives as stacked rows of ``pop``; rounds touch it
    only through an O(s·d) gather/scatter of the s sampled clients' rows,
    so the state layout scales to populations of 10^5+ clients. The legacy
    field names stay available as read-only views."""
    server: jnp.ndarray        # X_t  (d,)
    pop: Population            # rows: model (n,d), last_time (n,), lam,
    #                          # group, codec_up (EF state or ())
    t: jnp.ndarray             # server round
    sim_time: jnp.ndarray      # simulated wall-clock
    bits_up: jnp.ndarray       # cumulative client->server bits
    bits_down: jnp.ndarray     # cumulative server->client bits
    srv_dist_est: jnp.ndarray  # running ‖X_t − X^i‖ estimate (server Enc hint)

    @property
    def clients(self):
        """X^i stacked (n, d) — view into the population store."""
        return self.pop.rows["model"]

    @property
    def last_time(self):
        """(n,) last interaction time per client — view into the store."""
        return self.pop.rows["last_time"]

    @property
    def codec_up_state(self):
        """Per-client uplink-codec (EF) state; () for stateless codecs."""
        return self.pop.rows["codec_up"]

    def with_clients(self, clients) -> QuaflState:
        """Copy with the stacked client models replaced (test helper —
        the NamedTuple ``_replace`` can't target rows inside ``pop``)."""
        return self._replace(pop=with_rows(self.pop, model=clients))

    @property
    def bits_sent(self):
        """Total communication bits, both directions (legacy accessor)."""
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class QuAFL:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]     # (params_pytree, batch) -> (loss, m)
    template: Any                          # params pytree template
    batch_fn: Callable[[Any, jax.Array], Any]  # (client_data, key) -> batch
    avg_mode: str = "both"                 # 'both'|'server_only'|'client_only'
    uniform_speeds: bool = False
    exchange_impl: str = "pipeline"        # 'pipeline' | 'reference' (oracle)
    uplink: Any = None                     # codec spec (default: fed-derived)
    downlink: Any = None                   # codec spec (default: fed-derived)
    participation: Any = None              # spec (default: fed.participation)
    client_mesh: Any = None                # shard the store's client axis

    def __post_init__(self):
        backend = getattr(self.fed, "kernel_backend", "jnp")
        self.quant = make_quantizer(self.fed.quantizer, self.fed.bits,
                                    backend)
        n = self.fed.n_clients
        self.lam = speeds_for(self.fed, n, uniform=self.uniform_speeds)
        # per-direction codecs; the straggler mask resolves group specs
        # ({"fast": ..., "slow": ...}) into per-client bit budgets
        slow_mask = np.asarray(self.lam) == np.float32(self.fed.lam_slow)
        self.codec_up = resolve_codec(self.uplink, self.fed, direction="up",
                                      slow_mask=slow_mask)
        self.codec_down = resolve_codec(self.downlink, self.fed,
                                        direction="down")
        # rotated-space exchange engine whenever BOTH directions are
        # lattice-family (QSGD/identity/top-k have no rotation to
        # restructure around); shares every knob with the codecs so bit
        # accounting and γ derivation stay in lockstep
        self.pipeline = (ExchangePipeline(bits=self.codec_up.bits,
                                          block=self.codec_up.block,
                                          safety=self.codec_up.safety,
                                          backend=backend)
                         if (is_lattice_family(self.codec_up)
                             and is_lattice_family(self.codec_down))
                         else None)
        self.H = expected_steps(self.fed, self.lam)
        self.eta_i = ((self.H.min() / self.H) if self.fed.weighted
                      else np.ones(n)).astype(np.float32)
        # hoisted once — the traced round body only indexes these
        self._lam_j = jnp.asarray(self.lam)
        self._eta_j = jnp.asarray(self.eta_i)
        # who enters a round is a first-class spec on the clock
        self.part = resolve_participation(self.participation, self.fed)
        self.d = int(sum(np.prod(x.shape) for x in
                         jax.tree_util.tree_leaves(self.template)))

    # ------------------------------------------------------------------
    @property
    def _thread_ef(self) -> bool:
        """QuAFL's uplink is decoded against the SERVER model (non-zero
        reference), so error-feedback residuals — which assume the decoder
        reconstructs zero off the transmitted support — are only threaded
        for codecs that declare themselves reference-agnostic; everything
        else uses the stateless encode."""
        return self.codec_up.stateful and not getattr(
            self.codec_up, "ef_zero_ref_only", True)

    def _codec_state0(self):
        return (init_client_states(self.codec_up, self.fed.n_clients,
                                   self.d) if self._thread_ef else ())

    def init(self, params0) -> QuaflState:
        x0 = tree_flatten_vector(params0)
        n = self.fed.n_clients
        pop = build_population(self.fed, n, lam=self.lam,
                               model=jnp.tile(x0[None], (n, 1)),
                               last_time=jnp.zeros((n,)),
                               codec_up=self._codec_state0())
        if self.client_mesh is not None:
            pop = shard_population(pop, self.client_mesh)
        return QuaflState(
            server=x0, pop=pop,
            t=jnp.zeros((), jnp.int32), sim_time=jnp.zeros(()),
            bits_up=jnp.zeros(()), bits_down=jnp.zeros(()),
            srv_dist_est=jnp.ones(()) * 1e-3)

    # ------------------------------------------------------------------
    def _grad(self, flat, batch):
        def f(v):
            loss, _ = self.loss_fn(tree_unflatten_vector(self.template, v),
                                   batch)
            return loss
        return jax.grad(f)(flat)

    def _local_progress(self, flat, data_i, h_steps, key):
        """Replay up to K masked SGD steps; returns h̃ (sum of step grads)."""
        K, eta = self.fed.local_steps, self.fed.lr

        def step(carry, q):
            x, h = carry
            g = self._grad(x, self.batch_fn(data_i, jax.random.fold_in(key, q)))
            act = (q < h_steps).astype(jnp.float32)
            return (x - eta * act * g, h + act * g), None

        (_, h), _ = jax.lax.scan(step, (flat, jnp.zeros_like(flat)),
                                 jnp.arange(K))
        return h

    # ------------------------------------------------------------------
    @partial(jax.jit, static_argnums=0)
    def round(self, state: QuaflState, data, key):
        """One server round. data: stacked per-client datasets (n, ...)."""
        fed = self.fed
        n, s = fed.n_clients, fed.s
        k_sel, k_h, k_q, k_loc = jax.random.split(key, 4)

        # participation spec on the clock: who answers this round's poll.
        # Everything below touches the population only through the sampled
        # rows — O(s·d), independent of n.
        lam_row = state.pop.rows["lam"]
        idx = self.part.sample(k_sel, state.t, n, s, lam_row)
        got = gather_rows(state.pop, idx)
        elapsed = state.sim_time + fed.swt + fed.sit - got["last_time"]
        h_steps = self.part.h_steps(k_h, idx, got["lam"], elapsed,
                                    fed.local_steps)

        cl = got["model"]                                        # (s, d)
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)
        h_tilde = jax.vmap(self._local_progress)(cl, data_s, h_steps, keys)
        eta_i = self._eta_j[idx][:, None]
        prog = fed.lr * eta_i * h_tilde                          # η·η_i·h̃
        Y = cl - prog                                            # (s, d)

        # --- quantized exchange (shared per-interaction keys) -----------
        prog_norm = jnp.linalg.norm(prog, axis=1)
        hints_up = prog_norm + state.srv_dist_est + 1e-8
        cs_new = None          # sampled clients' updated EF rows (if any)

        if self.pipeline is not None:
            # rotated-space engine: one shared rotation per round, all
            # encode/decode/averaging in rotated coordinates (s+1 forward,
            # s+1 inverse full-model rotations — audited in the tests).
            # The per-direction codecs parameterize the wire (bit-width,
            # sub-byte packing, per-client levels) without touching the
            # rotation structure.
            fn = (self.pipeline.quafl_round
                  if self.exchange_impl == "pipeline"
                  else self.pipeline.quafl_round_reference)
            server_new, cl_new, hint_srv, rel_err = fn(
                k_q, state.server, Y, hints_up, avg_mode=self.avg_mode,
                up=self.codec_up.wire(idx), down=self.codec_down.wire())
        else:
            # scalar / identity / top-k: no rotation to restructure around
            kq_cl = jax.random.split(jax.random.fold_in(k_q, 1), s)

            if self._thread_ef:
                cs = got["codec_up"]            # gathered EF rows (s, ...)

                def enc_dec_up(y, kk, hint, cs_i):
                    msg, cs_i = self.codec_up.encode_stateful(
                        kk, y, hint, cs_i)
                    return self.codec_up.decode(kk, msg, state.server), cs_i

                QY, cs_new = jax.vmap(enc_dec_up)(Y, kq_cl, hints_up, cs)
            else:
                def enc_dec_up(y, kk, hint):
                    msg = self.codec_up.encode(kk, y, hint)
                    return self.codec_up.decode(kk, msg, state.server)

                QY = jax.vmap(enc_dec_up)(Y, kq_cl, hints_up)    # (s, d)

            # server -> clients: ONE encode, per-client decode vs own X^i
            kq_srv = jax.random.fold_in(k_q, 0)
            hint_srv = (jnp.max(jnp.linalg.norm(QY - state.server[None],
                                                axis=1)) + 1e-8)
            msg_srv = self.codec_down.encode(kq_srv, state.server, hint_srv)
            QX = jax.vmap(
                lambda ref: self.codec_down.decode(kq_srv, msg_srv,
                                                   ref))(cl)

            # --- averaging ------------------------------------------------
            if self.avg_mode == "both":
                server_new = (state.server + jnp.sum(QY, 0)) / (s + 1)
                cl_new = QX / (s + 1) + s * Y / (s + 1)
            elif self.avg_mode == "server_only":
                server_new = (state.server + jnp.sum(QY, 0)) / (s + 1)
                cl_new = QX
            elif self.avg_mode == "client_only":
                server_new = jnp.mean(QY, 0)
                cl_new = QX / (s + 1) + s * Y / (s + 1)
            else:  # 'none' — plain replacement both sides
                server_new = jnp.mean(QY, 0)
                cl_new = QX
            rel_err = jnp.mean(jnp.linalg.norm(QY - Y, axis=1)
                               / (jnp.linalg.norm(Y, axis=1) + 1e-9))

        # bit accounting, computed BY the codecs' wire formats: s uplink
        # messages (per-client widths under a grouped codec) + ONE downlink
        # broadcast Enc(X_t) (every sampled client decodes the same codes
        # against its own model)
        if isinstance(self.codec_up, GroupedLatticeCodec):
            bits_up = self.codec_up.bits_for(idx, self.d)   # traced sum
        else:
            bits_up = s * self.codec_up.message_bits(self.d)
        bits_down = self.codec_down.message_bits(self.d)
        dt = fed.swt + fed.sit
        new_time = state.sim_time + dt
        # scatter the s updated rows back into the store (O(s·d); untouched
        # rows pass through by reference so the scan carry stays donatable)
        updates = {"model": cl_new, "last_time": new_time}
        if cs_new is not None:
            updates["codec_up"] = cs_new
        state = QuaflState(
            server=server_new,
            pop=scatter_rows(state.pop, idx, updates),
            t=state.t + 1, sim_time=new_time,
            bits_up=state.bits_up + bits_up,
            bits_down=state.bits_down + bits_down,
            srv_dist_est=0.5 * state.srv_dist_est + 0.5 * hint_srv)
        metrics = {
            "sim_time": new_time,
            "round_time": jnp.asarray(dt, jnp.float32),
            "bits_up": jnp.asarray(bits_up, jnp.float32),
            "bits_down": jnp.asarray(bits_down, jnp.float32),
            "h_steps_mean": jnp.mean(h_steps.astype(jnp.float32)),
            "h_zero_frac": jnp.mean((h_steps == 0).astype(jnp.float32)),
            "quant_err": rel_err,
            "bits": jnp.asarray(bits_up + bits_down, jnp.float32),
        }
        return state, metrics

    # ------------------------------------------------------------------
    def device_round(self, state: QuaflState, data, key):
        """Device-resident round capability (:mod:`repro.fed.engine`): the
        round body is pure traced code — state a pytree, metrics device
        scalars — so the engine can ``lax.scan`` it in K-round chunks."""
        return self.round(state, data, key)

    def eval_params(self, state: QuaflState):
        return tree_unflatten_vector(self.template, state.server)

    def mean_model(self, state: QuaflState):
        mu = (state.server + jnp.sum(state.clients, 0)) / (self.fed.n_clients + 1)
        return tree_unflatten_vector(self.template, mu)
