"""Shard-local quantized exchange (§Perf optimization, beyond the paper).

The faithful baseline quantizes each parameter leaf GLOBALLY: the Hadamard
rotation reshapes the flattened leaf into 16k blocks that straddle shard
boundaries, so GSPMD inserts all-gathers before/after every rotation — the
dominant collective cost of the train step for the FSDP (cohort) archs.

Blockwise rotation is valid for ANY partition into blocks, so we instead run
the entire exchange inside one ``shard_map``: every device rotates/encodes/
decodes only its LOCAL chunk of every leaf (rotation key folded with the
model-axis index so codes stay decodable across the client axis), and the
only collectives left are the ones the ALGORITHM requires:

  * hint psums (scalar per leaf),
  * the client-sum for the server update, carried by a pluggable
    :class:`repro.compression.transports.Transport` strategy — fp32 psum
    (``shard_local``), an all-gather of the packed codec codes
    (``code_allgather``; with ``lattice_packed`` the gathered bytes shrink
    by the packing factor), or the fused ``reduce_scatter`` path that
    psum-scatters the SNAPPED rotated chunks and re-gathers them as a
    scatter-resident COMPRESSED downlink: each device lattice-encodes its
    own reduced shard at the downlink wire width and the all-gather moves
    packed integer codes + the γ-shards row instead of fp32 (the exchange
    derives the shared redistribution scale γ_rs from psum'd hints here,
    where the model axes are known, and hands it to the transport).

Semantics are an exact instance of Alg. 1 with a different (shard-aligned)
rotation block partition; ``shard_local`` and ``code_allgather`` compute
the same aggregate exactly, the fused ``reduce_scatter`` up to its
redistribution quantization (bounded like any downlink encode).

Compression is codec-composable: ``quant_up`` / ``quant_down`` are
:mod:`repro.compression.codecs` objects resolved per direction. A
lattice-family pair runs the ROTATED-SPACE path through the compression
pipeline — 3 forward passes per chunk (the fused rotate+encode of the
client update Y, the server rotation that serves as the uplink decode
reference, and the server's fused downlink encode, whose γ depends on the
decoded uplink), every snap/sum on rotated coordinates via the fused
kernels, only the two new states inverse-rotated (2 passes); the per-
direction wire descriptors thread bit-widths and sub-byte packing into the
kernels. Any other codec pair runs the per-message composition with the
same collective structure. The downlink Enc(X_t) is decoded against the
client's CURRENT model Y^i — the same reference rule as the flat
simulator's pipeline.quafl_round. Rounding noise is folded with the client
index; rotation keys remain shared across clients so codes stay
cross-decodable.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compression.codecs import is_lattice_family
from repro.compression.pipeline import ExchangePipeline, LatticeWire
from repro.utils.compat import shard_map
from repro.utils.tree import fold_in_str


def _pad1024(x):
    d = x.shape[0]
    pad = (-d) % 1024
    return (jnp.pad(x, (0, pad)) if pad else x), d


def rs_gamma(pipe: ExchangePipeline, wire_dn: LatticeWire, h_sum, nrm_sum,
             d: int):
    """Redistribution scale of the scatter-resident coded downlink.

    ``h_sum`` is the psum over clients of the per-client snap distances
    ‖QYᵢ − rot(X_t)‖: by the triangle inequality it upper-bounds
    ‖Σᵢ QYᵢ − n·rot(X_t)‖, so the aggregate satisfies the Lemma 3.1 wrap
    condition at this γ. Factored out so the γ-overflow interval analysis
    (``repro.analysis.intervals``) proves the wrap window on the SAME
    traced derivation the exchange runs.
    """
    wire_rs = LatticeWire(bits=wire_dn.bits, pack=wire_dn.pack)
    return pipe.gammas(h_sum[None], nrm_sum[None], d, wire_rs), wire_rs


def make_shardlocal_exchange(quant_up, quant_down, mesh,
                             srv_pspecs: Dict[str, P],
                             cl_pspecs: Dict[str, P], client_axis: str,
                             n_slots: int, transport):
    """Returns exchange(server, clients, Ys, key) -> (server_new,
    clients_new, qerr) with all quantization math device-local.

    ``quant_up`` / ``quant_down`` are per-direction codecs;
    ``transport`` a :class:`repro.compression.transports.Transport`
    carrying the uplink client-sum collective.
    """
    mesh_axes = list(mesh.shape.keys())
    model_axes = tuple(a for a in mesh_axes if a != client_axis)
    client_in_mesh = client_axis in mesh.shape
    denom = n_slots + 1
    lattice_pair = (is_lattice_family(quant_up)
                    and is_lattice_family(quant_down))
    pipe = (ExchangePipeline(bits=quant_up.bits, block=quant_up.block,
                             safety=quant_up.safety,
                             backend=quant_up.backend)
            if lattice_pair else None)
    wire_up = quant_up.wire() if lattice_pair else None
    wire_dn = quant_down.wire() if lattice_pair else None

    def _psum_norm(sq, axes):
        for a in axes:
            sq = jax.lax.psum(sq, a)
        return jnp.sqrt(sq)

    def _lattice_leaf(kk, srv, y, cl_flat):
        """Rotated-space exchange of one local leaf chunk: 3 forward + 2
        inverse rotation passes with the chunk-shared key (cl_flat only
        feeds the uplink hint; the downlink decodes against y)."""
        d = srv.shape[0]
        kk_cl = (jax.lax.axis_index(client_axis) if client_in_mesh else 0)
        k_up, k_dn = jax.random.fold_in(kk, 1), jax.random.fold_in(kk, 2)
        signs = pipe.signs_for(jax.random.split(k_up)[0], d)
        d_pad = signs.shape[0]

        # hints: ||Y - X^i|| over the model axes (client-local value)
        h_up = _psum_norm(jnp.sum(jnp.square(y - cl_flat)),
                          model_axes) + 1e-8
        gam_up = pipe.gammas(h_up[None], jnp.linalg.norm(y)[None], d,
                             wire_up)
        u_up = jax.random.uniform(
            jax.random.fold_in(jax.random.split(k_up)[1], kk_cl),
            (1, d_pad), jnp.float32)
        y_rot, codes = pipe.rotate_encode(y[None], signs, u_up, gam_up,
                                          wire=wire_up)
        srv_rot = pipe.rotate(srv[None], signs)
        qy_own = pipe.snap(codes, srv_rot, gam_up, wire_up)      # rotated
        # per-client distance to the decode reference (feeds the downlink
        # hint and, summed over clients, the coded-redistribution scale)
        h_cl = _psum_norm(jnp.sum(jnp.square(qy_own - srv_rot)), model_axes)
        # client-sum strategy: the pluggable transport decides which bytes
        # cross the interconnect (fp32 partials, packed codes, or the
        # scatter-resident coded shards of the fused reduce_scatter path)
        fused_rs = getattr(transport, "lattice_fused_sum", None)
        if fused_rs is not None and client_in_mesh:
            # ‖Σ QYᵢ − n·rot(X_t)‖ ≤ Σᵢ‖QYᵢ − rot(X_t)‖: the psum of the
            # per-client hints satisfies the wrap bound for the aggregate
            h_rs = jax.lax.psum(h_cl, client_axis) + 1e-8
            nrm_rs = jax.lax.psum(
                _psum_norm(jnp.sum(jnp.square(qy_own)), model_axes),
                client_axis)
            gam_rs, wire_rs = rs_gamma(pipe, wire_dn, h_rs, nrm_rs, d)
            k_rs = jax.random.fold_in(jax.random.split(k_dn)[0], kk_cl)
            qy_sum = fused_rs(pipe, wire_rs, qy_own, srv_rot, gam_rs,
                              k_rs, client_axis)
        else:
            qy_sum = transport.lattice_sum(pipe, wire_up, codes, gam_up,
                                           srv_rot, qy_own, client_axis,
                                           client_in_mesh,
                                           quant_up.code_dtype())
        srv_new_rot = (srv_rot + qy_sum) / denom

        # server -> client: encode once (same on every client slice),
        # decode against the client's current model Y — all in rotated
        # space, same reference rule as pipeline.quafl_round
        h_dn = h_cl
        if client_in_mesh:
            h_dn = jax.lax.pmax(h_dn, client_axis)
        gam_dn = pipe.gammas(2.0 * h_dn[None] + 1e-8,
                             jnp.linalg.norm(srv)[None], d, wire_dn)
        u_dn = jax.random.uniform(jax.random.split(k_dn)[1], (1, d_pad),
                                  jnp.float32)
        codes_dn = pipe.rotate_encode(srv[None], signs, u_dn, gam_dn,
                                      want_rotated=False, wire=wire_dn)
        qx_rot = pipe.snap(codes_dn, y_rot, gam_dn, wire_dn)
        cl_new_rot = qx_rot / denom + n_slots * y_rot / denom

        srv_new = pipe.unrotate(srv_new_rot, signs, d)[0]
        cl_new = pipe.unrotate(cl_new_rot, signs, d)[0]
        qerr = jnp.sum(jnp.square(qy_own[0] - y_rot[0])) / n_slots
        return srv_new, cl_new, qerr

    def _generic_leaf(kk, srv, y, cl_flat):
        """Per-message composition for codec pairs without a shared
        rotation structure (scalar / identity / top-k / mixed)."""
        h_up = _psum_norm(jnp.sum(jnp.square(y - cl_flat)),
                          model_axes) + 1e-8
        k_up = jax.random.fold_in(kk, 1)
        msg = quant_up.encode(k_up, y, h_up)
        qy_own = quant_up.decode(k_up, msg, srv)
        qy_sum = transport.generic_sum(quant_up, k_up, msg, srv, qy_own,
                                       client_axis, client_in_mesh,
                                       n_slots)
        srv_new = (srv + qy_sum) / denom

        h_dn = _psum_norm(jnp.sum(jnp.square(qy_own - srv)), model_axes)
        if client_in_mesh:
            h_dn = jax.lax.pmax(h_dn, client_axis)
        k_dn = jax.random.fold_in(kk, 2)
        msg_s = quant_down.encode(k_dn, srv, 2.0 * h_dn + 1e-8)
        qx = quant_down.decode(k_dn, msg_s, cl_flat)
        cl_new = qx / denom + n_slots * y / denom
        qerr = jnp.sum(jnp.square(qy_own - y)) / n_slots
        return srv_new, cl_new, qerr

    leaf_fn = _lattice_leaf if pipe is not None else _generic_leaf

    def local_fn(server_l, clients_l, Ys_l, key):
        key = jax.random.wrap_key_data(key)
        # identity along the NON-client axes selects the rotation block; it
        # must be shared along the client axis so codes stay decodable.
        mid = 0
        for a in model_axes:
            mid = mid * mesh.shape[a] + jax.lax.axis_index(a)
        qerr = jnp.zeros((), jnp.float32)
        server_new, clients_new = {}, {}
        for k in server_l:
            kk = jax.random.fold_in(fold_in_str(key, k), mid)
            srv, _ = _pad1024(server_l[k].astype(jnp.float32).ravel())
            cl = clients_l[k][0]
            y, dlen = _pad1024(Ys_l[k][0].astype(jnp.float32).ravel())
            cl_flat, _ = _pad1024(cl.astype(jnp.float32).ravel())

            srv_new, cl_new, qerr_k = leaf_fn(kk, srv, y, cl_flat)
            qerr += qerr_k
            shp, dt = server_l[k].shape, server_l[k].dtype
            server_new[k] = srv_new[:dlen].reshape(shp).astype(dt)
            clients_new[k] = cl_new[:dlen].reshape((1,) + shp).astype(
                clients_l[k].dtype)
        for a in model_axes:
            qerr = jax.lax.psum(qerr, a)
        # qerr varies per client slot (each device quantizes its own Y^i);
        # committing it replicated (out_spec P()) without reducing over the
        # client axis would publish client 0's value — the divergence class
        # repro.analysis.divergence flags. Reduce to the sum over clients.
        if client_in_mesh:
            qerr = jax.lax.psum(qerr, client_axis)
        return server_new, clients_new, qerr

    in_specs = (srv_pspecs, cl_pspecs, cl_pspecs, P())
    out_specs = (srv_pspecs, cl_pspecs, P())
    fn = shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)

    def exchange(server, clients, Ys, key_data):
        return fn(server, clients, Ys, key_data)

    return exchange
