"""Shard-local quantized exchange (§Perf optimization, beyond the paper).

The faithful baseline quantizes each parameter leaf GLOBALLY: the Hadamard
rotation reshapes the flattened leaf into 16k blocks that straddle shard
boundaries, so GSPMD inserts all-gathers before/after every rotation — the
dominant collective cost of the train step for the FSDP (cohort) archs.

Blockwise rotation is valid for ANY partition into blocks, so we instead run
the entire exchange inside one ``shard_map``: every device rotates/encodes/
decodes only its LOCAL chunk of every leaf (rotation key folded with the
model-axis index so codes stay decodable across the client axis), and the
only collectives left are the ones the ALGORITHM requires:

  * hint psums (scalar per leaf),
  * the client-sum for the server update — fp32 psum over the client axis
    ('dequant_psum') or an all-gather of packed uint codes + local decode
    ('code_allgather').

Semantics are an exact instance of Alg. 1 with a different (shard-aligned)
rotation block partition.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.utils.tree import fold_in_str


def _pad1024(x):
    d = x.shape[0]
    pad = (-d) % 1024
    return (jnp.pad(x, (0, pad)) if pad else x), d


def make_shardlocal_exchange(quant, mesh, srv_pspecs: Dict[str, P],
                             cl_pspecs: Dict[str, P], client_axis: str,
                             n_slots: int, codes_transport: bool):
    """Returns exchange(server, clients, Ys, key) -> (server_new,
    clients_new, qerr) with all quantization math device-local."""
    mesh_axes = list(mesh.shape.keys())
    model_axes = tuple(a for a in mesh_axes if a != client_axis)
    client_in_mesh = client_axis in mesh.shape
    denom = n_slots + 1

    def local_fn(server_l, clients_l, Ys_l, key):
        key = jax.random.wrap_key_data(key)
        # identity along the NON-client axes selects the rotation block; it
        # must be shared along the client axis so codes stay decodable.
        mid = 0
        for a in model_axes:
            mid = mid * mesh.shape[a] + jax.lax.axis_index(a)
        qerr = jnp.zeros((), jnp.float32)
        server_new, clients_new = {}, {}
        for k in server_l:
            kk = jax.random.fold_in(fold_in_str(key, k), mid)
            srv, _ = _pad1024(server_l[k].astype(jnp.float32).ravel())
            cl = clients_l[k][0]
            y, dlen = _pad1024(Ys_l[k][0].astype(jnp.float32).ravel())
            cl_flat, _ = _pad1024(cl.astype(jnp.float32).ravel())

            # hints: ||Y - X^i|| over the model axes (client-local value)
            h_up = jnp.sum(jnp.square(y - cl_flat))
            for a in model_axes:
                h_up = jax.lax.psum(h_up, a)
            h_up = jnp.sqrt(h_up) + 1e-8

            kk_cl = (jax.lax.axis_index(client_axis) if client_in_mesh
                     else 0)
            k_up = jax.random.fold_in(kk, 1)
            msg = quant.encode(k_up, y, h_up)
            if codes_transport and client_in_mesh:
                codes_all = jax.lax.all_gather(msg.codes, client_axis)
                gam_all = jax.lax.all_gather(msg.gamma, client_axis)
                qy_sum = jnp.zeros_like(srv)
                for j in range(n_slots):
                    m_j = type(msg)(codes=codes_all[j], gamma=gam_all[j])
                    qy_sum = qy_sum + quant.decode(k_up, m_j, srv)
                qy_own = quant.decode(k_up, msg, srv)
            else:
                qy_own = quant.decode(k_up, msg, srv)
                qy_sum = qy_own
                if client_in_mesh:
                    qy_sum = jax.lax.psum(qy_own, client_axis)
            srv_new = (srv + qy_sum) / denom

            # server -> client: encode once (same on every client slice),
            # decode against the local client chunk
            h_dn = jnp.sum(jnp.square(qy_own - srv))
            for a in model_axes:
                h_dn = jax.lax.psum(h_dn, a)
            h_dn = jnp.sqrt(h_dn)
            if client_in_mesh:
                h_dn = jax.lax.pmax(h_dn, client_axis)
            k_dn = jax.random.fold_in(kk, 2)
            msg_s = quant.encode(k_dn, srv, 2.0 * h_dn + 1e-8)
            qx = quant.decode(k_dn, msg_s, cl_flat)
            cl_new = qx / denom + n_slots * y / denom

            qerr += jnp.sum(jnp.square(qy_own - y)) / n_slots
            shp, dt = server_l[k].shape, server_l[k].dtype
            server_new[k] = srv_new[:dlen].reshape(shp).astype(dt)
            clients_new[k] = cl_new[:dlen].reshape((1,) + shp).astype(
                clients_l[k].dtype)
        for a in model_axes:
            qerr = jax.lax.psum(qerr, a)
        return server_new, clients_new, qerr

    in_specs = (srv_pspecs, cl_pspecs, cl_pspecs, P())
    out_specs = (srv_pspecs, cl_pspecs, P())
    fn = jax.shard_map(local_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)

    def exchange(server, clients, Ys, key_data):
        return fn(server, clients, Ys, key_data)

    return exchange
