"""Synchronous FedAvg baselines (paper App. A.2 + compressed variants).

:class:`FedAvg` — paper App. A.2 'FedAvg' specification: each round the
server sends its model to s random clients; each performs EXACTLY K local
steps and returns the result; the server averages. The server must wait for
the SLOWEST sampled client: simulated round time = max_i Gamma(K, λ_i) +
sit (swt = 0 in FedAvg). The speed model and the straggler draw come from
``repro.fed.clock`` — the same clock every algorithm in the comparison runs
under. Registry name ``"fedavg"``.

Codecs: FedAvg defaults to ``identity`` both ways (the paper's
uncompressed baseline, bit-for-bit the historical implementation), but any
:mod:`repro.compression.codecs` spec plugs in per direction — uplink
messages are the client models decoded against the server (position-aware
reference), the downlink distortion is a broadcast Enc(X_t) each sampled
client decodes before starting its local steps.

:class:`CompressedFedAvg` — registry name ``"compressed_fedavg"``: the
FedPAQ / compressed-FedAvg family (arXiv:2106.07155; controlled averaging
with compression, arXiv:2308.08165) built PURELY from the codec API as
composition proof. Clients upload codec-compressed model DELTAS (decoded
against the zero vector — the sound reference for every codec, including
non-position-aware ``scalar``), the server applies the averaged decoded
delta with a server learning rate, and the downlink is ONE broadcast
Enc(X_t) decoded against the previous round's server model. Stateful
codecs (``topk_ef``) get their per-client error-feedback residuals
threaded through the state.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.codecs import (IdentityCodec, init_client_states,
                                      resolve_codec)
from repro.configs.base import FedConfig
from repro.fed.clock import (sample_clients, speeds_for,  # noqa: F401
                             straggler_round_time)
from repro.fed.population import (Population, build_population,
                                  resolve_participation, scatter_rows,
                                  shard_population)
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


class FedAvgState(NamedTuple):
    server: jnp.ndarray
    pop: Population            # per-client rows: lam, group
    t: jnp.ndarray
    sim_time: jnp.ndarray
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray

    @property
    def bits_sent(self):
        """Total communication bits, both directions (legacy accessor)."""
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class FedAvg:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]
    uniform_speeds: bool = False
    uplink: Any = None                  # codec spec (default: identity)
    downlink: Any = None                # codec spec (default: identity)
    participation: Any = None           # spec (default: fed.participation)
    client_mesh: Any = None             # shard the store's client axis
    # subclasses override the per-direction codec defaults (None = the
    # legacy fed.quantizer map)
    _codec_default_up = "identity"
    _codec_default_down = "identity"

    def __post_init__(self):
        n = self.fed.n_clients
        self.lam = speeds_for(self.fed, n, uniform=self.uniform_speeds)
        self.part = resolve_participation(self.participation, self.fed)
        self.d = int(sum(np.prod(x.shape) for x in
                         jax.tree_util.tree_leaves(self.template)))
        self.codec_up = resolve_codec(self.uplink, self.fed, direction="up",
                                      default=self._codec_default_up)
        self.codec_down = resolve_codec(self.downlink, self.fed,
                                        direction="down",
                                        default=self._codec_default_down)
        self._up_identity = isinstance(self.codec_up, IdentityCodec)
        self._down_identity = isinstance(self.codec_down, IdentityCodec)
        # stateful codecs degrade gracefully to their stateless encode here
        # (fedavg clients keep no cross-round memory); compressed_fedavg
        # threads real per-client error-feedback residuals

    def _pop0(self, **extra_rows) -> Population:
        pop = build_population(self.fed, self.fed.n_clients, lam=self.lam,
                               **extra_rows)
        if self.client_mesh is not None:
            pop = shard_population(pop, self.client_mesh)
        return pop

    def init(self, params0) -> FedAvgState:
        return FedAvgState(server=tree_flatten_vector(params0),
                           pop=self._pop0(),
                           t=jnp.zeros((), jnp.int32),
                           sim_time=jnp.zeros(()), bits_up=jnp.zeros(()),
                           bits_down=jnp.zeros(()))

    def _grad(self, flat, batch):
        def f(v):
            loss, _ = self.loss_fn(tree_unflatten_vector(self.template, v),
                                   batch)
            return loss
        return jax.grad(f)(flat)

    def _local(self, start, data_i, kk):
        """EXACTLY K local SGD steps from ``start``."""
        K = self.fed.local_steps

        def step(x, q):
            g = self._grad(x, self.batch_fn(data_i,
                                            jax.random.fold_in(kk, q)))
            return x - self.fed.lr * g, None

        x, _ = jax.lax.scan(step, start, jnp.arange(K))
        return x

    @partial(jax.jit, static_argnums=0)
    def round(self, state: FedAvgState, data, key):
        fed = self.fed
        n, s, K = fed.n_clients, fed.s, fed.local_steps
        k_sel, k_loc, k_t = jax.random.split(key, 3)
        # codec keys derive via fold_in so the legacy (identity/identity)
        # key schedule — and hence the PR 3 trace — is untouched
        k_q = jax.random.fold_in(key, 17)
        idx = self.part.sample(k_sel, state.t, n, s, state.pop.rows["lam"])
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)

        # downlink: ONE broadcast Enc(X_t); every sampled client decodes it
        # against the server reference before stepping. The identity pair
        # skips the codec calls entirely — the uncompressed baseline keeps
        # the paper's round cost (no extra O(s·d) norm reductions)
        if self._down_identity:
            start = state.server
        else:
            k_dn = jax.random.fold_in(k_q, 0)
            msg_dn = self.codec_down.encode(k_dn, state.server,
                                            jnp.asarray(1e-8, jnp.float32))
            start = self.codec_down.decode(k_dn, msg_dn, state.server)

        models = jax.vmap(lambda di, kk: self._local(start, di, kk))(
            data_s, keys)

        # uplink: client models decoded against the server (position-aware
        # reference)
        if self._up_identity:
            QY = models
            rel_err = jnp.zeros(())
        else:
            kq_cl = jax.random.split(jax.random.fold_in(k_q, 1), s)
            hints = jnp.linalg.norm(models - state.server[None],
                                    axis=1) + 1e-8

            def enc_dec(x, kk, hint):
                return self.codec_up.decode(
                    kk, self.codec_up.encode(kk, x, hint), state.server)

            QY = jax.vmap(enc_dec)(models, kq_cl, hints)
            rel_err = jnp.mean(jnp.linalg.norm(QY - models, axis=1)
                               / (jnp.linalg.norm(models, axis=1) + 1e-9))
        server_new = jnp.mean(QY, 0)
        # slowest sampled client: sum of K Exp(λ) step times
        dt = straggler_round_time(k_t, state.pop.rows["lam"][idx], K,
                                  fed.sit)
        # wire accounting by the codecs: s unicasts each way
        bits_up = s * self.codec_up.message_bits(self.d)
        bits_down = s * self.codec_down.message_bits(self.d)
        metrics = {
            "sim_time": state.sim_time + dt,
            "round_time": dt,
            "bits_up": jnp.asarray(bits_up, jnp.float32),
            "bits_down": jnp.asarray(bits_down, jnp.float32),
            "h_steps_mean": jnp.asarray(K, jnp.float32),  # exactly K, always
            "quant_err": rel_err,
            "bits": jnp.asarray(bits_up + bits_down, jnp.float32),
        }
        return FedAvgState(server=server_new, pop=state.pop, t=state.t + 1,
                           sim_time=state.sim_time + dt,
                           bits_up=state.bits_up + bits_up,
                           bits_down=state.bits_down + bits_down), metrics

    def device_round(self, state: FedAvgState, data, key):
        """Device-resident round capability (:mod:`repro.fed.engine`)."""
        return self.round(state, data, key)

    def eval_params(self, state):
        return tree_unflatten_vector(self.template, state.server)


# ---------------------------------------------------------------------------
# compressed FedAvg (FedPAQ family) — registry name "compressed_fedavg"
# ---------------------------------------------------------------------------

class CompressedFedAvgState(NamedTuple):
    server: jnp.ndarray
    pop: Population            # rows: lam, group, codec_up (EF residuals)
    t: jnp.ndarray
    sim_time: jnp.ndarray
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray
    srv_prev: jnp.ndarray      # previous server model (downlink decode ref)
    srv_dist_est: jnp.ndarray  # running ‖X_t − X_{t-1}‖ (downlink Enc hint)

    @property
    def codec_up_state(self):
        """Per-client error-feedback residuals — a population row."""
        return self.pop.rows["codec_up"]

    @property
    def bits_sent(self):
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class CompressedFedAvg(FedAvg):
    """Compressed synchronous FedAvg, composed purely from the codec API.

    Uplink: per-client model deltas, codec-encoded with hint ‖Δ‖ and
    decoded against the ZERO vector (sound for position-aware and scalar
    codecs alike — exactly FedPAQ when ``uplink="scalar"``). Downlink: one
    broadcast Enc(X_t) decoded against the previous server model (every
    client received that broadcast last round). Defaults: uplink from the
    legacy ``fed.quantizer`` map (lattice at ``fed.bits``), downlink
    ``identity``.
    """
    server_lr: float = 1.0
    # uplink defaults to the legacy fed.quantizer map (None), downlink to
    # the uncompressed broadcast; downlink stateful codecs degrade to
    # their stateless encode (one broadcast encoder; only uplink
    # residuals are threaded)
    _codec_default_up = None
    _codec_default_down = "identity"

    def _codec_state0(self):
        return init_client_states(self.codec_up, self.fed.n_clients,
                                  self.d)

    def init(self, params0) -> CompressedFedAvgState:
        x0 = tree_flatten_vector(params0)
        return CompressedFedAvgState(
            server=x0, pop=self._pop0(codec_up=self._codec_state0()),
            t=jnp.zeros((), jnp.int32), sim_time=jnp.zeros(()),
            bits_up=jnp.zeros(()), bits_down=jnp.zeros(()),
            # a COPY: server and srv_prev must never alias (the scanned
            # engine donates the state, and XLA rejects donating one
            # buffer twice)
            srv_prev=jnp.array(x0), srv_dist_est=jnp.ones(()) * 1e-3)

    @partial(jax.jit, static_argnums=0)
    def round(self, state: CompressedFedAvgState, data, key):
        fed = self.fed
        n, s, K = fed.n_clients, fed.s, fed.local_steps
        k_sel, k_loc, k_t = jax.random.split(key, 3)
        k_q = jax.random.fold_in(key, 17)
        idx = self.part.sample(k_sel, state.t, n, s, state.pop.rows["lam"])
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)

        # downlink broadcast: Enc(X_t) decoded against X_{t-1}
        k_dn = jax.random.fold_in(k_q, 0)
        msg_dn = self.codec_down.encode(k_dn, state.server,
                                        state.srv_dist_est + 1e-8)
        start = self.codec_down.decode(k_dn, msg_dn, state.srv_prev)

        models = jax.vmap(lambda di, kk: self._local(start, di, kk))(
            data_s, keys)
        deltas = start[None] - models                     # descent direction

        # uplink: codec-compressed deltas decoded against zero
        kq_cl = jax.random.split(jax.random.fold_in(k_q, 1), s)
        hints = jnp.linalg.norm(deltas, axis=1) + 1e-12
        zero = jnp.zeros((self.d,), jnp.float32)
        pop_new = state.pop

        if self.codec_up.stateful:
            cs = jax.tree_util.tree_map(lambda a: a[idx],
                                        state.codec_up_state)

            def enc_dec(dl, kk, hint, cs_i):
                msg, cs_i = self.codec_up.encode_stateful(kk, dl, hint, cs_i)
                return self.codec_up.decode(kk, msg, zero), cs_i

            QD, cs_new = jax.vmap(enc_dec)(deltas, kq_cl, hints, cs)
            # scatter the sampled clients' EF residuals back (O(s·d))
            pop_new = scatter_rows(state.pop, idx, {"codec_up": cs_new})
        else:
            def enc_dec(dl, kk, hint):
                return self.codec_up.decode(
                    kk, self.codec_up.encode(kk, dl, hint), zero)

            QD = jax.vmap(enc_dec)(deltas, kq_cl, hints)

        server_new = state.server - self.server_lr * jnp.mean(QD, 0)
        rel_err = jnp.mean(jnp.linalg.norm(QD - deltas, axis=1)
                           / (jnp.linalg.norm(deltas, axis=1) + 1e-12))
        dt = straggler_round_time(k_t, state.pop.rows["lam"][idx], K,
                                  fed.sit)
        bits_up = s * self.codec_up.message_bits(self.d)
        bits_down = self.codec_down.message_bits(self.d)  # ONE broadcast
        new_time = state.sim_time + dt
        new_state = CompressedFedAvgState(
            server=server_new, pop=pop_new, t=state.t + 1,
            sim_time=new_time,
            bits_up=state.bits_up + bits_up,
            bits_down=state.bits_down + bits_down,
            srv_prev=state.server,
            srv_dist_est=0.5 * state.srv_dist_est
            + 0.5 * jnp.linalg.norm(server_new - state.server))
        metrics = {
            "sim_time": new_time,
            "round_time": dt,
            "bits_up": jnp.asarray(bits_up, jnp.float32),
            "bits_down": jnp.asarray(bits_down, jnp.float32),
            "h_steps_mean": jnp.asarray(K, jnp.float32),
            "quant_err": rel_err,
            "bits": jnp.asarray(bits_up + bits_down, jnp.float32),
        }
        return new_state, metrics

    def device_round(self, state: CompressedFedAvgState, data, key):
        """Device-resident round capability (:mod:`repro.fed.engine`)."""
        return self.round(state, data, key)
