"""Synchronous FedAvg baseline (paper App. A.2 'FedAvg' specification).

Each round the server sends its (uncompressed) model to s random clients;
each performs EXACTLY K local steps and returns the result; the server
averages. The server must wait for the SLOWEST sampled client: simulated
round time = max_i Gamma(K, λ_i) + sit (swt = 0 in FedAvg). The speed model
and the straggler draw come from ``repro.fed.clock`` — the same clock every
algorithm in the comparison runs under.

Implements the :class:`repro.fed.FedAlgorithm` protocol; registry name
``"fedavg"``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.fed.clock import sample_clients, speeds_for, straggler_round_time
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


class FedAvgState(NamedTuple):
    server: jnp.ndarray
    t: jnp.ndarray
    sim_time: jnp.ndarray
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray

    @property
    def bits_sent(self):
        """Total communication bits, both directions (legacy accessor)."""
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class FedAvg:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]
    uniform_speeds: bool = False

    def __post_init__(self):
        n = self.fed.n_clients
        self.lam = speeds_for(self.fed, n, uniform=self.uniform_speeds)
        self.d = int(sum(np.prod(x.shape) for x in
                         jax.tree_util.tree_leaves(self.template)))

    def init(self, params0) -> FedAvgState:
        return FedAvgState(server=tree_flatten_vector(params0),
                           t=jnp.zeros((), jnp.int32),
                           sim_time=jnp.zeros(()), bits_up=jnp.zeros(()),
                           bits_down=jnp.zeros(()))

    def _grad(self, flat, batch):
        def f(v):
            loss, _ = self.loss_fn(tree_unflatten_vector(self.template, v),
                                   batch)
            return loss
        return jax.grad(f)(flat)

    @partial(jax.jit, static_argnums=0)
    def round(self, state: FedAvgState, data, key):
        fed = self.fed
        n, s, K = fed.n_clients, fed.s, fed.local_steps
        k_sel, k_loc, k_t = jax.random.split(key, 3)
        idx = sample_clients(k_sel, n, s)
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)

        def local(data_i, kk):
            def step(x, q):
                g = self._grad(x, self.batch_fn(data_i,
                                                jax.random.fold_in(kk, q)))
                return x - fed.lr * g, None
            x, _ = jax.lax.scan(step, state.server, jnp.arange(K))
            return x

        models = jax.vmap(local)(data_s, keys)
        server_new = jnp.mean(models, 0)
        # slowest sampled client: sum of K Exp(λ) step times
        dt = straggler_round_time(k_t, jnp.asarray(self.lam)[idx], K, fed.sit)
        bits_up = bits_down = s * self.d * 32  # uncompressed both ways
        metrics = {
            "sim_time": state.sim_time + dt,
            "round_time": dt,
            "bits_up": jnp.asarray(bits_up, jnp.float32),
            "bits_down": jnp.asarray(bits_down, jnp.float32),
            "h_steps_mean": jnp.asarray(K, jnp.float32),  # exactly K, always
            "quant_err": jnp.zeros(()),                   # uncompressed
            "bits": jnp.asarray(bits_up + bits_down, jnp.float32),
        }
        return FedAvgState(server=server_new, t=state.t + 1,
                           sim_time=state.sim_time + dt,
                           bits_up=state.bits_up + bits_up,
                           bits_down=state.bits_down + bits_down), metrics

    def device_round(self, state: FedAvgState, data, key):
        """Device-resident round capability (:mod:`repro.fed.engine`)."""
        return self.round(state, data, key)

    def eval_params(self, state):
        return tree_unflatten_vector(self.template, state.server)
