"""The paper's primary contribution: QuAFL (Alg. 1) plus the baselines it is
compared against (FedAvg, FedBuff, sequential) and the beyond-paper
extensions. Every class implements the :class:`repro.fed.FedAlgorithm`
protocol; prefer selecting by name via ``repro.fed.make_algorithm``."""
from repro.core.quafl import QuAFL, QuaflState, client_speeds, expected_steps  # noqa: F401
from repro.core.fedavg import (CompressedFedAvg,  # noqa: F401
                               CompressedFedAvgState, FedAvg, FedAvgState)
from repro.core.fedbuff import (FedBuff, FedBuffDevice,  # noqa: F401
                                FedBuffDeviceState, FedBuffState)
from repro.core.baseline import BaselineState, Sequential  # noqa: F401
from repro.core.extensions import (AdaptiveBits, AdaptiveQuAFL,  # noqa: F401
                                   AdaptiveQuaflAlgorithm, AdaptiveState,
                                   QuaflScaffold, ScaffoldState)
