"""The paper's primary contribution: QuAFL (Alg. 1) plus the baselines it is
compared against (FedAvg, FedBuff, sequential)."""
from repro.core.quafl import QuAFL, QuaflState, client_speeds, expected_steps  # noqa: F401
from repro.core.fedavg import FedAvg, FedAvgState  # noqa: F401
from repro.core.fedbuff import FedBuff  # noqa: F401
from repro.core.baseline import Sequential  # noqa: F401
from repro.core.extensions import AdaptiveBits, AdaptiveQuAFL, QuaflScaffold  # noqa: F401
