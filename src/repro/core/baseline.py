"""Sequential baseline (paper Fig. 3/10): a single (slow) node performing one
optimization step per round, acting as both client and server."""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


class BaselineState(NamedTuple):
    server: jnp.ndarray
    t: jnp.ndarray
    sim_time: jnp.ndarray


@dataclass(eq=False)
class Sequential:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]

    def init(self, params0):
        return BaselineState(server=tree_flatten_vector(params0),
                             t=jnp.zeros((), jnp.int32),
                             sim_time=jnp.zeros(()))

    @partial(jax.jit, static_argnums=0)
    def round(self, state, data, key):
        def f(v, batch):
            loss, _ = self.loss_fn(tree_unflatten_vector(self.template, v),
                                   batch)
            return loss
        data0 = jax.tree_util.tree_map(lambda a: a[0], data)
        k_b, k_t = jax.random.split(key)
        g = jax.grad(f)(state.server, self.batch_fn(data0, k_b))
        # a single SLOW node: Exp(λ_slow) step duration
        dt = jax.random.exponential(k_t) / self.fed.lam_slow
        return BaselineState(server=state.server - self.fed.lr * g,
                             t=state.t + 1,
                             sim_time=state.sim_time + dt), {}

    def eval_params(self, state):
        return tree_unflatten_vector(self.template, state.server)
