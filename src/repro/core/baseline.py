"""Sequential baseline (paper Fig. 3/10): a single (slow) node performing one
optimization step per round, acting as both client and server.

Implements the :class:`repro.fed.FedAlgorithm` protocol; registry name
``"sequential"``. There is no communication, so both bit counters stay 0 —
the fields exist so the unified metrics schema holds for every algorithm.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


class BaselineState(NamedTuple):
    server: jnp.ndarray
    t: jnp.ndarray
    sim_time: jnp.ndarray
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray

    @property
    def bits_sent(self):
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class Sequential:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]

    def init(self, params0):
        return BaselineState(server=tree_flatten_vector(params0),
                             t=jnp.zeros((), jnp.int32),
                             sim_time=jnp.zeros(()), bits_up=jnp.zeros(()),
                             bits_down=jnp.zeros(()))

    @partial(jax.jit, static_argnums=0)
    def round(self, state, data, key):
        def f(v, batch):
            loss, _ = self.loss_fn(tree_unflatten_vector(self.template, v),
                                   batch)
            return loss
        data0 = jax.tree_util.tree_map(lambda a: a[0], data)
        k_b, k_t = jax.random.split(key)
        g = jax.grad(f)(state.server, self.batch_fn(data0, k_b))
        # a single SLOW node: Exp(λ_slow) step duration
        dt = jax.random.exponential(k_t) / self.fed.lam_slow
        new_time = state.sim_time + dt
        metrics = {
            "sim_time": new_time,
            "round_time": dt,
            "bits_up": jnp.zeros(()), "bits_down": jnp.zeros(()),
            "h_steps_mean": jnp.ones(()),   # one step per round, by design
            "quant_err": jnp.zeros(()),
        }
        return BaselineState(server=state.server - self.fed.lr * g,
                             t=state.t + 1, sim_time=new_time,
                             bits_up=state.bits_up,
                             bits_down=state.bits_down), metrics

    def device_round(self, state: BaselineState, data, key):
        """Device-resident round capability (:mod:`repro.fed.engine`)."""
        return self.round(state, data, key)

    def eval_params(self, state):
        return tree_unflatten_vector(self.template, state.server)
