"""Beyond-paper extensions the paper's §5 names as future work.

1. **QuAFL-SCAFFOLD** — controlled averaging [Karimireddy et al., 15] on top
   of Alg. 1: every client keeps a control variate c_i and the server keeps
   c; local steps use g̃ − c_i + c, and the sampled clients' control updates
   ride the SAME quantized exchange (the lattice quantizer is position-aware
   w.r.t. the previous control estimate, so the extra message costs the same
   b bits/coordinate). Reduces client drift under non-iid data — exactly the
   G² term that dominates QuAFL's heterogeneous bound.

2. **Adaptive bit-width** (cf. AdaQuantFL [Jhunjhunwala et al., 12], which
   the paper cites as iid-only): the server tracks the measured relative
   quantization error of decoded client messages and walks b up/down between
   rounds to keep it inside a target band. Works with the lattice quantizer
   because γ already adapts to the model distance — bits only control the
   wrap-window safety margin.

Both implement the :class:`repro.fed.FedAlgorithm` protocol — registry names
``"quafl_scaffold"`` and ``"adaptive_quafl"`` — so they run through the same
``simulate()`` harness and metrics schema as every paper algorithm. The
legacy ``AdaptiveQuAFL`` wrapper (internally-held state, ``round(data,
key)``) remains as a thin shim over the protocol implementation.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.core.quafl import QuAFL, QuaflState
from repro.fed.clock import lazy_h_steps, sample_clients  # noqa: F401
from repro.fed.population import gather_rows, scatter_rows, with_rows


class ScaffoldState(NamedTuple):
    base: QuaflState
    c_server: jnp.ndarray      # server control variate (d,)

    @property
    def c_clients(self):
        """Per-client control variates (n, d) — a row of the base state's
        population store (gathered/scattered with the model rows)."""
        return self.base.pop.rows["control"]

    @property
    def bits_sent(self):
        return self.base.bits_sent


@dataclass(eq=False)
class QuaflScaffold(QuAFL):
    """QuAFL with SCAFFOLD control variates (option-II updates).

    Both model and control messages ride the ``uplink`` codec; the two
    downlink broadcasts ride the ``downlink`` codec. Stateful codecs
    degrade to their stateless encode (the control-variate stream has no
    error-feedback slot to thread)."""

    def init(self, params0) -> ScaffoldState:
        base = super().init(params0)
        n = self.fed.n_clients
        z = jnp.zeros_like(base.server)
        # the control variates are one more per-client row of the store
        base = base._replace(pop=with_rows(
            base.pop, control=jnp.zeros((n, z.shape[0]))))
        return ScaffoldState(base=base, c_server=z)

    def _local_progress_controlled(self, flat, data_i, h_steps, key, c_corr):
        K, eta = self.fed.local_steps, self.fed.lr

        def step(carry, q):
            x, h = carry
            g = self._grad(x, self.batch_fn(data_i,
                                            jax.random.fold_in(key, q)))
            g = g - c_corr            # SCAFFOLD correction: -c_i + c
            act = (q < h_steps).astype(jnp.float32)
            return (x - eta * act * g, h + act * g), None

        (_, h), _ = jax.lax.scan(step, (flat, jnp.zeros_like(flat)),
                                 jnp.arange(K))
        return h

    @partial(jax.jit, static_argnums=0)
    def round(self, state: ScaffoldState, data, key):
        fed = self.fed
        n, s = fed.n_clients, fed.s
        base = state.base
        k_sel, k_h, k_q, k_loc = jax.random.split(key, 4)
        idx = self.part.sample(k_sel, base.t, n, s, base.pop.rows["lam"])
        got = gather_rows(base.pop, idx)
        elapsed = base.sim_time + fed.swt + fed.sit - got["last_time"]
        h_steps = self.part.h_steps(k_h, idx, got["lam"], elapsed,
                                    fed.local_steps)

        cl = got["model"]
        c_i = got["control"]
        c_corr = c_i - state.c_server[None, :]
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)
        h_tilde = jax.vmap(self._local_progress_controlled)(
            cl, data_s, h_steps, keys, c_corr)
        eta_i = jnp.asarray(self.eta_i)[idx][:, None]
        Y = cl - fed.lr * eta_i * h_tilde

        # control update (option II): c_i+ = c_i − c + h̃/H_i
        steps = jnp.maximum(h_steps.astype(jnp.float32), 1.0)[:, None]
        c_new = c_i - state.c_server[None, :] + h_tilde / steps

        # quantized exchange — model messages vs X_t, control messages vs
        # the PREVIOUS client control (position-aware both ways)
        kq_cl = jax.random.split(jax.random.fold_in(k_q, 1), s)
        prog = jnp.linalg.norm(fed.lr * eta_i * h_tilde, axis=1)

        def updn(y, cn, ci, kk, hint):
            m1 = self.codec_up.encode(kk, y, hint + 1e-8)
            qy = self.codec_up.decode(kk, m1, base.server)
            kk2 = jax.random.fold_in(kk, 17)
            m2 = self.codec_up.encode(kk2, cn,
                                      jnp.linalg.norm(cn - ci) + 1e-8)
            qc = self.codec_up.decode(kk2, m2, ci)
            return qy, qc

        QY, QC = jax.vmap(updn)(Y, c_new, c_i, kq_cl,
                                prog + base.srv_dist_est)

        server_new = (base.server + jnp.sum(QY, 0)) / (s + 1)
        c_server_new = state.c_server + jnp.sum(QC - c_i, 0) / n

        kq_srv = jax.random.fold_in(k_q, 0)
        hint_srv = jnp.max(jnp.linalg.norm(QY - base.server[None], axis=1)) \
            + 1e-8
        msg = self.codec_down.encode(kq_srv, base.server, hint_srv)
        QX = jax.vmap(lambda r: self.codec_down.decode(kq_srv, msg, r))(cl)
        cl_new = QX / (s + 1) + s * Y / (s + 1)

        # 2 codec messages per sampled client up (model + control), 2 down
        # (the broadcast Enc(X_t) + the control broadcast) — wire accounting
        # by the per-direction codecs
        bits_up = 2 * s * self.codec_up.message_bits(self.d)
        bits_down = 2 * self.codec_down.message_bits(self.d)
        dt = fed.swt + fed.sit
        new_time = base.sim_time + dt
        # one scatter covers models, interaction times, AND control rows
        # (codec/EF rows pass through untouched — scaffold runs stateless
        # encodes — keeping the pytree structure stable for the scan)
        nbase = QuaflState(
            server=server_new,
            pop=scatter_rows(base.pop, idx,
                             {"model": cl_new, "last_time": new_time,
                              "control": QC}),
            t=base.t + 1, sim_time=new_time,
            bits_up=base.bits_up + bits_up,
            bits_down=base.bits_down + bits_down,
            srv_dist_est=0.5 * base.srv_dist_est + 0.5 * hint_srv)
        new_state = ScaffoldState(base=nbase, c_server=c_server_new)
        rel_err = jnp.mean(jnp.linalg.norm(QY - Y, axis=1)
                           / (jnp.linalg.norm(Y, axis=1) + 1e-9))
        metrics = {"sim_time": new_time,
                   "round_time": jnp.asarray(dt, jnp.float32),
                   "bits_up": jnp.asarray(bits_up, jnp.float32),
                   "bits_down": jnp.asarray(bits_down, jnp.float32),
                   "h_steps_mean": jnp.mean(h_steps.astype(jnp.float32)),
                   "h_zero_frac": jnp.mean((h_steps == 0).astype(jnp.float32)),
                   "quant_err": rel_err,
                   "c_norm": jnp.linalg.norm(c_server_new)}
        return new_state, metrics

    def eval_params(self, state: ScaffoldState):
        return super().eval_params(state.base)


# ---------------------------------------------------------------------------
# adaptive bit-width controller
# ---------------------------------------------------------------------------

@dataclass
class AdaptiveBits:
    """Walks the bit-width to keep the measured relative quantization error
    inside [lo, hi]. Bits are part of the round's shared parametrization
    (server announces b with the poll), so adapting them is free."""
    bits: int = 8
    lo: float = 0.01
    hi: float = 0.05
    b_min: int = 4
    b_max: int = 16

    @staticmethod
    def walk(bits: int, rel_err: float, lo: float, hi: float,
             b_min: int, b_max: int) -> int:
        """Pure controller step — the stateless core shared with the
        protocol implementation. The result always stays in [b_min, b_max]
        for in-range inputs."""
        if rel_err > hi and bits < b_max:
            return bits + 1
        if rel_err < lo and bits > b_min:
            return bits - 1
        return bits

    def update(self, rel_err: float) -> int:
        self.bits = self.walk(self.bits, rel_err, self.lo, self.hi,
                              self.b_min, self.b_max)
        return self.bits


_TRACE_CAP = 4096   # bounds the per-round tuple copy; full history is in
                    # the per-round "bits_width" metric every round emits


@dataclass
class AdaptiveState:
    """Protocol state: the wrapped QuAFL state + the python-int bit-width
    (it selects the jit cache, so it cannot live on-device) + the visited
    bit-width trace (immutable so forked states stay independent; capped at
    the last ``_TRACE_CAP`` entries to keep the per-round copy bounded)."""
    inner: QuaflState
    bits: int
    trace: Tuple[int, ...] = ()

    @property
    def sim_time(self):
        return self.inner.sim_time

    @property
    def bits_sent(self):
        return self.inner.bits_sent


class AdaptiveQuaflAlgorithm:
    """Adaptive bit-width QuAFL as a :class:`repro.fed.FedAlgorithm`.

    Composition over a QuAFL factory: one QuAFL instance per active
    bit-width (jit cache friendly — at most b_max − b_min compilations).
    The bit walk reacts to the measured ``quant_err`` of the previous round.
    """

    def __init__(self, fed: FedConfig, make_alg, *, lo: float = 0.01,
                 hi: float = 0.05, b_min: int = 4, b_max: int = 16):
        self.fed = fed
        self.make_alg = make_alg
        self.lo, self.hi, self.b_min, self.b_max = lo, hi, b_min, b_max
        self._algs = {}
        self._engines = {}   # bits -> RoundEngine over that bit-width's alg

    def _alg(self, bits: int):
        if bits not in self._algs:
            import dataclasses
            self._algs[bits] = self.make_alg(
                dataclasses.replace(self.fed, bits=bits))
        return self._algs[bits]

    def init(self, params0) -> AdaptiveState:
        return AdaptiveState(inner=self._alg(self.fed.bits).init(params0),
                             bits=self.fed.bits)

    def round(self, state: AdaptiveState, data, key):
        alg = self._alg(state.bits)
        inner, m = alg.round(state.inner, data, key)
        rel = float(m["quant_err"]) if "quant_err" in m else 0.02
        new_bits = AdaptiveBits.walk(state.bits, rel, self.lo, self.hi,
                                     self.b_min, self.b_max)
        metrics = {**m, "bits_width": float(state.bits)}
        return AdaptiveState(
            inner=inner, bits=new_bits,
            trace=(state.trace + (state.bits,))[-_TRACE_CAP:]), metrics

    def scan_rounds(self, state: AdaptiveState, data, key, length: int):
        """Chunked scan support (:class:`repro.fed.engine.RoundEngine`).

        The bit-width selects a jit cache, so it cannot change inside a
        traced chunk: the chunk runs at the state's CURRENT bits and the
        walk reacts ONCE per chunk, to the chunk's last measured
        ``quant_err`` — chunk-level adaptation instead of the eager path's
        round-level adaptation, in exchange for one host sync per chunk.
        ``scan_chunk=1`` recovers the eager walk exactly.
        """
        from repro.fed.engine import RoundEngine
        eng = self._engines.get(state.bits)
        if eng is None:
            eng = self._engines[state.bits] = RoundEngine(
                self._alg(state.bits))
        key, inner, ms = eng.run_chunk(state.inner, data, key, length)
        rel = float(ms["quant_err"][-1])   # the chunk-boundary host sync
        new_bits = AdaptiveBits.walk(state.bits, rel, self.lo, self.hi,
                                     self.b_min, self.b_max)
        ms = dict(ms)
        ms["bits_width"] = jnp.full((length,), float(state.bits))
        new_state = AdaptiveState(
            inner=inner, bits=new_bits,
            trace=(state.trace + (state.bits,) * length)[-_TRACE_CAP:])
        return key, new_state, ms

    def eval_params(self, state: AdaptiveState):
        return self._alg(state.bits).eval_params(state.inner)


class AdaptiveQuAFL:
    """Legacy wrapper (internally-held state): thin shim over
    :class:`AdaptiveQuaflAlgorithm` preserving the original interface."""

    def __init__(self, fed: FedConfig, make_alg, params0):
        self.fed = fed
        self.make_alg = make_alg
        self.params0 = params0
        self._impl = AdaptiveQuaflAlgorithm(fed, make_alg)
        self.state = self._impl.init(params0)

    @property
    def bits_trace(self):
        return list(self.state.trace)

    def round(self, data, key):
        self.state, m = self._impl.round(self.state, data, key)
        return m

    def eval_params(self):
        return self._impl.eval_params(self.state)
