"""Beyond-paper extensions the paper's §5 names as future work.

1. **QuAFL-SCAFFOLD** — controlled averaging [Karimireddy et al., 15] on top
   of Alg. 1: every client keeps a control variate c_i and the server keeps
   c; local steps use g̃ − c_i + c, and the sampled clients' control updates
   ride the SAME quantized exchange (the lattice quantizer is position-aware
   w.r.t. the previous control estimate, so the extra message costs the same
   b bits/coordinate). Reduces client drift under non-iid data — exactly the
   G² term that dominates QuAFL's heterogeneous bound.

2. **Adaptive bit-width** (cf. AdaQuantFL [Jhunjhunwala et al., 12], which
   the paper cites as iid-only): the server tracks the measured relative
   quantization error of decoded client messages and walks b up/down between
   rounds to keep it inside a target band. Works with the lattice quantizer
   because γ already adapts to the model distance — bits only control the
   wrap-window safety margin.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.lattice import make_quantizer
from repro.configs.base import FedConfig
from repro.core.quafl import QuAFL, QuaflState


class ScaffoldState(NamedTuple):
    base: QuaflState
    c_server: jnp.ndarray      # server control variate (d,)
    c_clients: jnp.ndarray     # per-client control variates (n, d)


@dataclass(eq=False)
class QuaflScaffold(QuAFL):
    """QuAFL with SCAFFOLD control variates (option-II updates)."""

    def init(self, params0) -> ScaffoldState:
        base = super().init(params0)
        n = self.fed.n_clients
        z = jnp.zeros_like(base.server)
        return ScaffoldState(base=base, c_server=z,
                             c_clients=jnp.zeros((n, z.shape[0])))

    def _local_progress_controlled(self, flat, data_i, h_steps, key, c_corr):
        K, eta = self.fed.local_steps, self.fed.lr

        def step(carry, q):
            x, h = carry
            g = self._grad(x, self.batch_fn(data_i,
                                            jax.random.fold_in(key, q)))
            g = g - c_corr            # SCAFFOLD correction: -c_i + c
            act = (q < h_steps).astype(jnp.float32)
            return (x - eta * act * g, h + act * g), None

        (_, h), _ = jax.lax.scan(step, (flat, jnp.zeros_like(flat)),
                                 jnp.arange(K))
        return h

    @partial(jax.jit, static_argnums=0)
    def round(self, state: ScaffoldState, data, key):
        fed = self.fed
        n, s = fed.n_clients, fed.s
        base = state.base
        k_sel, k_h, k_q, k_loc = jax.random.split(key, 4)
        idx = jax.random.choice(k_sel, n, (s,), replace=False)
        elapsed = base.sim_time + fed.swt + fed.sit - base.last_time[idx]
        lam = jnp.asarray(self.lam)[idx]
        h_steps = jnp.minimum(jax.random.poisson(k_h, lam * elapsed),
                              fed.local_steps).astype(jnp.int32)

        cl = base.clients[idx]
        c_i = state.c_clients[idx]
        c_corr = c_i - state.c_server[None, :]
        data_s = jax.tree_util.tree_map(lambda a: a[idx], data)
        keys = jax.random.split(k_loc, s)
        h_tilde = jax.vmap(self._local_progress_controlled)(
            cl, data_s, h_steps, keys, c_corr)
        eta_i = jnp.asarray(self.eta_i)[idx][:, None]
        Y = cl - fed.lr * eta_i * h_tilde

        # control update (option II): c_i+ = c_i − c + h̃/H_i
        steps = jnp.maximum(h_steps.astype(jnp.float32), 1.0)[:, None]
        c_new = c_i - state.c_server[None, :] + h_tilde / steps

        # quantized exchange — model messages vs X_t, control messages vs
        # the PREVIOUS client control (position-aware both ways)
        kq_cl = jax.random.split(jax.random.fold_in(k_q, 1), s)
        prog = jnp.linalg.norm(fed.lr * eta_i * h_tilde, axis=1)

        def updn(y, cn, ci, kk, hint):
            m1 = self.quant.encode(kk, y, hint + 1e-8)
            qy = self.quant.decode(kk, m1, base.server)
            kk2 = jax.random.fold_in(kk, 17)
            m2 = self.quant.encode(kk2, cn,
                                   jnp.linalg.norm(cn - ci) + 1e-8)
            qc = self.quant.decode(kk2, m2, ci)
            return qy, qc

        QY, QC = jax.vmap(updn)(Y, c_new, c_i, kq_cl,
                                prog + base.srv_dist_est)

        server_new = (base.server + jnp.sum(QY, 0)) / (s + 1)
        c_server_new = state.c_server + jnp.sum(QC - c_i, 0) / n

        kq_srv = jax.random.fold_in(k_q, 0)
        hint_srv = jnp.max(jnp.linalg.norm(QY - base.server[None], axis=1)) \
            + 1e-8
        msg = self.quant.encode(kq_srv, base.server, hint_srv)
        QX = jax.vmap(lambda r: self.quant.decode(kq_srv, msg, r))(cl)
        cl_new = QX / (s + 1) + s * Y / (s + 1)

        new_time = base.sim_time + fed.swt + fed.sit
        nbase = QuaflState(
            server=server_new, clients=base.clients.at[idx].set(cl_new),
            t=base.t + 1, sim_time=new_time,
            last_time=base.last_time.at[idx].set(new_time),
            bits_sent=base.bits_sent
            + 2 * (s + 1) * self.quant.message_bits(self.d),
            srv_dist_est=0.5 * base.srv_dist_est + 0.5 * hint_srv)
        new_state = ScaffoldState(
            base=nbase, c_server=c_server_new,
            c_clients=state.c_clients.at[idx].set(QC))
        metrics = {"h_steps_mean": jnp.mean(h_steps.astype(jnp.float32)),
                   "c_norm": jnp.linalg.norm(c_server_new)}
        return new_state, metrics

    def eval_params(self, state: ScaffoldState):
        return super().eval_params(state.base)


# ---------------------------------------------------------------------------
# adaptive bit-width controller
# ---------------------------------------------------------------------------

@dataclass
class AdaptiveBits:
    """Walks the bit-width to keep the measured relative quantization error
    inside [lo, hi]. Bits are part of the round's shared parametrization
    (server announces b with the poll), so adapting them is free."""
    bits: int = 8
    lo: float = 0.01
    hi: float = 0.05
    b_min: int = 4
    b_max: int = 16

    def update(self, rel_err: float) -> int:
        if rel_err > self.hi and self.bits < self.b_max:
            self.bits += 1
        elif rel_err < self.lo and self.bits > self.b_min:
            self.bits -= 1
        return self.bits


class AdaptiveQuAFL:
    """Composition wrapper: a QuAFL instance per active bit-width (jit cache
    friendly — at most b_max − b_min compilations)."""

    def __init__(self, fed: FedConfig, make_alg, params0):
        self.fed = fed
        self.make_alg = make_alg
        self.ctrl = AdaptiveBits(bits=fed.bits)
        self._algs = {}
        self.params0 = params0
        self.state = self._alg(fed.bits).init(params0)
        self.bits_trace = []

    def _alg(self, bits: int):
        if bits not in self._algs:
            import dataclasses
            self._algs[bits] = self.make_alg(
                dataclasses.replace(self.fed, bits=bits))
        return self._algs[bits]

    def round(self, data, key):
        alg = self._alg(self.ctrl.bits)
        self.state, m = alg.round(self.state, data, key)
        rel = float(m["quant_err"]) if "quant_err" in m else 0.02
        self.bits_trace.append(self.ctrl.bits)
        self.ctrl.update(rel)
        return m

    def eval_params(self):
        return self._alg(self.ctrl.bits).eval_params(self.state)
