"""FedBuff baseline [Nguyen et al., 30]: buffered asynchronous aggregation.

Clients run continuously; when client i finishes its K local steps (duration
Gamma(K, λ_i)) it ships the model DELTA to a shared buffer and restarts from
the current server model. Once the buffer holds Z updates the server applies
the averaged delta. The deltas can be quantized (``quantize=True``) with:

  * ``quantizer="qsgd"``    — the paper's Fig. 6/16 variant. FedBuff cannot
    lattice-quantize *models* (the server has no decoding key for a
    client's stale base model)…
  * ``quantizer="lattice"`` — …but the DELTA is position-aware decodable
    against the zero vector with hint ‖Δ‖, so delta compression rides the
    same fused rotate+quantize pipeline as QuAFL (backend selected by
    ``FedConfig.kernel_backend``). Beyond-paper option.

Event-driven python loop around a jitted local-steps function (FedBuff's
control flow is data-dependent, so it is simulated rather than SPMD)."""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.lattice import make_quantizer
from repro.configs.base import FedConfig
from repro.core.quafl import client_speeds
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


@dataclass(eq=False)
class FedBuff:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]
    buffer_size: int = 10
    server_lr: float = 1.0
    quantize: bool = False
    quantizer: str = "qsgd"   # 'qsgd' (paper) | 'lattice' (delta-vs-zero)
    uniform_speeds: bool = False

    def __post_init__(self):
        n = self.fed.n_clients
        self.lam = (np.full(n, self.fed.lam_fast, np.float32)
                    if self.uniform_speeds else client_speeds(self.fed, n))
        self.quant = make_quantizer(self.quantizer if self.quantize
                                    else "none", self.fed.bits,
                                    getattr(self.fed, "kernel_backend",
                                            "jnp"))
        self.d = int(sum(np.prod(x.shape) for x in
                         jax.tree_util.tree_leaves(self.template)))

        @partial(jax.jit)
        def _local(server_flat, data_i, key):
            def f(v, batch):
                loss, _ = self.loss_fn(
                    tree_unflatten_vector(self.template, v), batch)
                return loss

            def step(x, q):
                g = jax.grad(f)(x, self.batch_fn(
                    data_i, jax.random.fold_in(key, q)))
                return x - self.fed.lr * g, None

            x, _ = jax.lax.scan(step, server_flat,
                                jnp.arange(self.fed.local_steps))
            return server_flat - x  # delta (positive direction of descent)

        self._local = _local

    def run(self, params0, data, key, total_time: float, eval_every: float,
            eval_fn):
        """Simulate until ``total_time``; returns list of (time, metrics)."""
        rng = np.random.default_rng(
            int(jax.random.randint(key, (), 0, 2**31 - 1)))
        n, K = self.fed.n_clients, self.fed.local_steps
        server = tree_flatten_vector(params0)
        start_model = [server for _ in range(n)]
        events: List = []
        for i in range(n):
            heapq.heappush(events, (rng.gamma(K, 1.0 / self.lam[i]), i))
        buffer, history, next_eval, bits = [], [], 0.0, 0
        jkey = key
        while events:
            t_now, i = heapq.heappop(events)
            if t_now > total_time:
                break
            while t_now >= next_eval:
                history.append((next_eval, eval_fn(tree_unflatten_vector(
                    self.template, server)), bits))
                next_eval += eval_every
            jkey, sub = jax.random.split(jkey)
            delta = self._local(start_model[i], jax.tree_util.tree_map(
                lambda a: a[i], data), sub)
            if self.quantize:
                jkey, qk = jax.random.split(jkey)
                # lattice path: deltas are position-aware decodable against
                # the zero vector with hint ‖Δ‖ (one fused encode + decode
                # pass through the pipeline backend); QSGD ignores both.
                msg = self.quant.encode(
                    qk, delta, jnp.linalg.norm(delta) + 1e-12)
                delta = self.quant.decode(qk, msg, jnp.zeros_like(delta))
                bits += self.quant.message_bits(self.d)
            else:
                bits += self.d * 32
            buffer.append(delta)
            if len(buffer) >= self.buffer_size:
                # Δ = start − end = η·Σg points downhill: w ← w − η_g·avg(Δ)
                server = server - self.server_lr * jnp.mean(
                    jnp.stack(buffer), 0)
                buffer = []
            # client restarts from the current server model
            start_model[i] = server
            heapq.heappush(events,
                           (t_now + rng.gamma(K, 1.0 / self.lam[i]), i))
        while next_eval <= total_time:
            history.append((next_eval, eval_fn(tree_unflatten_vector(
                self.template, server)), bits))
            next_eval += eval_every
        return history
