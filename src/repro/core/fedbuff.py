"""FedBuff baseline [Nguyen et al., 30]: buffered asynchronous aggregation.

Clients run continuously; when client i finishes its K local steps (duration
Gamma(K, λ_i)) it ships the model DELTA to a shared buffer and restarts from
the current server model. Once the buffer holds Z updates the server applies
the averaged delta. The deltas can be quantized (``quantize=True``) with:

  * ``quantizer="qsgd"``    — the paper's Fig. 6/16 variant. FedBuff cannot
    lattice-quantize *models* (the server has no decoding key for a
    client's stale base model)…
  * ``quantizer="lattice"`` — …but the DELTA is position-aware decodable
    against the zero vector with hint ‖Δ‖, so delta compression rides the
    same fused rotate+quantize pipeline as QuAFL (backend selected by
    ``FedConfig.kernel_backend``). Beyond-paper option.

Both knobs are now views over the composable codec API: ``uplink=`` /
``downlink=`` specs (or ``FedConfig.codec_up`` / ``codec_down``) select
ANY registered codec per direction — the legacy quantize/quantizer pair
maps onto the equivalent codec so seeded legacy runs are unchanged draw
for draw, and a stateful uplink codec (``topk_ef``) gets its per-client
error-feedback residuals threaded through ``FedBuffState.ef`` on this
python implementation.

FedBuff's control flow is data-dependent, so it is simulated (event-driven
python around a jitted local-steps function) rather than SPMD. The event
machinery — ``Gamma(K, λ)`` completion times feeding a min-heap of arrivals —
lives in ``repro.fed.clock`` (the same clock every baseline runs under).

The class implements the :class:`repro.fed.FedAlgorithm` protocol: ``round``
advances the event simulation until ONE buffer flush (one server update) and
returns the standardized metrics. The state is a python-side record (not a
jax pytree) — rounds are deterministic given ``init`` plus the FIRST round
key, which seeds the event rng exactly like the legacy ``run`` entry point;
later round keys are ignored. ``run`` is a thin wrapper over the same
single-completion step: the event order, rng stream, and model iterates are
identical to the legacy loop. The history's bits column now counts BOTH
directions (each restart downloads the fp32 server model, d·32 bits, on top
of the uplink delta) — the legacy loop counted the uplink only.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Callable, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.codecs import IdentityCodec, resolve_codec
from repro.compression.lattice import make_quantizer
from repro.configs.base import FedConfig
from repro.fed.clock import (ArrivalQueue, completion_time,
                             completion_time_device, speeds_for)
from repro.fed.engine import RingBuffer, ring_init, ring_pop, ring_push
from repro.fed.population import (Population, build_population,
                                  shard_population, with_rows)
from repro.utils.tree import tree_flatten_vector, tree_unflatten_vector


def _copy_rng(rng: np.random.Generator) -> np.random.Generator:
    new = np.random.default_rng()
    new.bit_generator.state = rng.bit_generator.state
    return new


@dataclass
class FedBuffState:
    """Event-driven simulation state (python-side; NOT a jax pytree)."""
    server: jnp.ndarray
    start_model: List[jnp.ndarray]      # model each client started from
    queue: Optional[ArrivalQueue]       # pending completion events
    buffer: List[jnp.ndarray]           # deltas awaiting the next flush
    sim_time: float = 0.0
    t: int = 0                          # server updates applied
    bits_up: float = 0.0
    bits_down: float = 0.0
    rng: Optional[np.random.Generator] = None   # seeded on first round
    jkey: Optional[jax.Array] = None
    ef: Optional[List] = None           # per-client residuals of a stateful
    #                                   # (error-feedback) uplink codec

    @property
    def bits_sent(self):
        """Total communication bits, both directions (legacy accessor)."""
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class FedBuff:
    fed: FedConfig
    loss_fn: Callable[[Any, Any], Any]
    template: Any
    batch_fn: Callable[[Any, jax.Array], Any]
    buffer_size: int = 10
    server_lr: float = 1.0
    quantize: bool = False
    quantizer: str = "qsgd"   # 'qsgd' (paper) | 'lattice' (delta-vs-zero)
    uniform_speeds: bool = False
    uplink: Any = None        # codec spec; default derives from the legacy
    #                         # quantize/quantizer knobs (identity when off)
    downlink: Any = None      # codec spec for the restart broadcast
    #                         # (default identity: fp32 server model)

    def __post_init__(self):
        n = self.fed.n_clients
        self.lam = speeds_for(self.fed, n, uniform=self.uniform_speeds)
        self.quant = make_quantizer(self.quantizer if self.quantize
                                    else "none", self.fed.bits,
                                    getattr(self.fed, "kernel_backend",
                                            "jnp"))
        # per-direction codecs; the legacy quantize/quantizer pair maps to
        # the equivalent codec (qsgd -> scalar, lattice -> lattice) so
        # seeded legacy runs are unchanged draw for draw
        legacy_up = ({"qsgd": "scalar", "lattice": "lattice",
                      "none": "identity"}.get(self.quantizer, "identity")
                     if self.quantize else "identity")
        self.codec_up = resolve_codec(self.uplink, self.fed, direction="up",
                                      default=legacy_up)
        self.codec_down = resolve_codec(self.downlink, self.fed,
                                        direction="down",
                                        default="identity")
        self._down_identity = isinstance(self.codec_down, IdentityCodec)
        self._up_compressed = not isinstance(self.codec_up, IdentityCodec)
        self.d = int(sum(np.prod(x.shape) for x in
                         jax.tree_util.tree_leaves(self.template)))

        @partial(jax.jit)
        def _local(server_flat, data_i, key):
            def f(v, batch):
                loss, _ = self.loss_fn(
                    tree_unflatten_vector(self.template, v), batch)
                return loss

            def step(x, q):
                g = jax.grad(f)(x, self.batch_fn(
                    data_i, jax.random.fold_in(key, q)))
                return x - self.fed.lr * g, None

            x, _ = jax.lax.scan(step, server_flat,
                                jnp.arange(self.fed.local_steps))
            return server_flat - x  # delta (positive direction of descent)

        self._local = _local

    # ------------------------------------------------------------------
    # FedAlgorithm protocol
    # ------------------------------------------------------------------
    def init(self, params0) -> FedBuffState:
        server = tree_flatten_vector(params0)
        n = self.fed.n_clients
        ef = ([self.codec_up.init_state(self.d) for _ in range(n)]
              if self.codec_up.stateful else None)
        return FedBuffState(server=server,
                            start_model=[server for _ in range(n)],
                            queue=None, buffer=[], ef=ef)

    def _seed(self, state: FedBuffState, key) -> FedBuffState:
        """Seed the event rng from a jax key (legacy ``run`` derivation)."""
        rng = np.random.default_rng(
            int(jax.random.randint(key, (), 0, 2**31 - 1)))
        queue = ArrivalQueue.initial(rng, self.lam, self.fed.local_steps)
        return replace(state, rng=rng, queue=queue, jkey=key)

    @staticmethod
    def _fork(state: FedBuffState) -> FedBuffState:
        """Copy the mutable containers so the caller's state stays usable.

        Called ONCE per protocol ``round`` (not per completion event):
        ``_completion`` mutates in place, so a round of Z buffered arrivals
        costs one O(n_clients) copy instead of Z."""
        return replace(state, queue=state.queue.copy(),
                       start_model=list(state.start_model),
                       buffer=list(state.buffer), rng=_copy_rng(state.rng),
                       ef=None if state.ef is None else list(state.ef))

    def _completion(self, state: FedBuffState, data, want_metrics=False):
        """Process ONE client completion event, MUTATING ``state``.
        With ``want_metrics`` returns the relative quantization error of
        this delta as a DEVICE scalar (else/uncompressed: None) — the
        legacy ``run`` path skips the two extra full-model norms entirely,
        matching the work the original loop did."""
        t_now, i = state.queue.pop()
        state.jkey, sub = jax.random.split(state.jkey)
        delta = self._local(state.start_model[i], jax.tree_util.tree_map(
            lambda a: a[i], data), sub)
        rel_err = None
        if self._up_compressed:
            state.jkey, qk = jax.random.split(state.jkey)
            # deltas are decodable against the zero vector with hint ‖Δ‖
            # for every codec (position-aware lattice rides one fused
            # encode + decode pass; scalar/top-k ignore the reference);
            # stateful codecs thread the client's error-feedback residual
            hint = jnp.linalg.norm(delta) + 1e-12
            if self.codec_up.stateful:
                msg, state.ef[i] = self.codec_up.encode_stateful(
                    qk, delta, hint, state.ef[i])
            else:
                msg = self.codec_up.encode(qk, delta, hint)
            dq = self.codec_up.decode(qk, msg, jnp.zeros_like(delta))
            if want_metrics:
                rel_err = (jnp.linalg.norm(dq - delta)
                           / (jnp.linalg.norm(delta) + 1e-12))
            delta = dq
        state.bits_up += self.codec_up.message_bits(self.d)
        state.buffer.append(delta)
        if len(state.buffer) >= self.buffer_size:
            # Δ = start − end = η·Σg points downhill: w ← w − η_g·avg(Δ)
            state.server = state.server - self.server_lr * jnp.mean(
                jnp.stack(state.buffer), 0)
            state.buffer = []
            state.t += 1
        # client restarts from the downlinked server model: fp32 by
        # default, codec-encoded (decoded against the client's previous
        # start model — the reference it still holds) otherwise
        if self._down_identity:
            state.start_model[i] = state.server
        else:
            state.jkey, dk = jax.random.split(state.jkey)
            hint_dn = (jnp.linalg.norm(state.server - state.start_model[i])
                       + 1e-12)
            msg_dn = self.codec_down.encode(dk, state.server, hint_dn)
            state.start_model[i] = self.codec_down.decode(
                dk, msg_dn, state.start_model[i])
        state.bits_down += self.codec_down.message_bits(self.d)
        state.sim_time = float(t_now)
        state.queue.push(t_now + completion_time(
            state.rng, self.fed.local_steps, self.lam[i]), i)
        return rel_err

    def round(self, state: FedBuffState, data, key):
        """Advance the event simulation until ONE buffer flush (one server
        update). ``key`` seeds the rng on the first call only — the event
        stream is a single sequence, exactly as in the legacy ``run``. The
        input state is forked, not mutated."""
        if state.rng is None:
            state = self._seed(state, key)
        state = self._fork(state)
        t_before, errs = state.t, []
        time_before, up_before, down_before = (state.sim_time, state.bits_up,
                                               state.bits_down)
        while state.t == t_before:
            rel = self._completion(state, data, want_metrics=True)
            if rel is not None:
                errs.append(rel)
        metrics = {
            "sim_time": state.sim_time,
            "round_time": state.sim_time - time_before,
            "bits_up": state.bits_up - up_before,
            "bits_down": state.bits_down - down_before,
            # every buffered arrival carries exactly K completed steps
            "h_steps_mean": float(self.fed.local_steps),
            "quant_err": float(jnp.mean(jnp.stack(errs))) if errs else 0.0,
            "buffer_flushes": 1.0,
        }
        return state, metrics

    def eval_params(self, state: FedBuffState):
        return tree_unflatten_vector(self.template, state.server)

    # ------------------------------------------------------------------
    # legacy entry point (exact event/eval ordering of the original loop)
    # ------------------------------------------------------------------
    def run(self, params0, data, key, total_time: float, eval_every: float,
            eval_fn):
        """Simulate until ``total_time``; returns list of (time, metrics,
        bits). Bit-identical event stream to the protocol ``round`` path —
        both drive the same single-completion step in the same order."""
        state = self._seed(self.init(params0), key)
        history, next_eval = [], 0.0
        while len(state.queue):
            t_now, _ = state.queue.peek()
            if t_now > total_time:
                break
            while t_now >= next_eval:
                history.append((next_eval, eval_fn(self.eval_params(state)),
                                state.bits_sent))
                next_eval += eval_every
            self._completion(state, data)   # run() owns state: no fork
        while next_eval <= total_time:
            history.append((next_eval, eval_fn(self.eval_params(state)),
                            state.bits_sent))
            next_eval += eval_every
        return history


# ---------------------------------------------------------------------------
# device-resident formulation (jit/scan-able; registry name fedbuff_device)
# ---------------------------------------------------------------------------

class FedBuffDeviceState(NamedTuple):
    """Pure-pytree FedBuff state: the python heap becomes a fixed-capacity
    :class:`repro.fed.engine.RingBuffer` (one pending completion per client,
    so capacity = n_clients and the buffer is always exactly full). The
    per-client rows — restart models, draw counters, speeds — live in the
    :class:`Population` store; events touch single rows (O(d)), so the
    population size only sets memory, not per-event cost."""
    server: jnp.ndarray        # (d,)
    pop: Population            # rows: start (n,d), occ (n,) i32, lam, group
    queue: RingBuffer          # pending completion events
    sim_time: jnp.ndarray      # f32 scalar
    t: jnp.ndarray             # i32 server updates applied
    bits_up: jnp.ndarray       # f32 scalar
    bits_down: jnp.ndarray     # f32 scalar
    jkey: jax.Array            # event key stream (local steps + quantize)
    live: jnp.ndarray          # bool: queue/jkey seeded by the first round

    @property
    def start(self):
        """(n, d) model each client restarted from — a population row."""
        return self.pop.rows["start"]

    @property
    def occ(self):
        """(n,) per-client completion-draw counters — a population row."""
        return self.pop.rows["occ"]

    @property
    def bits_sent(self):
        """Total communication bits, both directions (legacy accessor)."""
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class FedBuffDevice(FedBuff):
    """Buffered asynchronous aggregation as PURE traced code.

    Semantically the same event simulation as :class:`FedBuff` — pop the
    earliest completion, compute the client's K-step delta, buffer it, flush
    every ``buffer_size`` arrivals, reschedule the client — but the state is
    a registered pytree and ``round`` is a single jit/scan-able program
    (``lax.scan`` over the Z completions of one flush; masked-min pop on the
    device ring buffer). This is what lets FedBuff join the scanned
    ``simulate()`` fast path and the SPMD engine (ROADMAP: "FedBuff protocol
    state, jit-able").

    Randomness: per-completion model/quantizer keys follow the legacy
    ``jkey`` split schedule exactly. Completion DURATIONS come from
    ``jax.random.gamma`` (device stream) by default — same distribution,
    different draws than the legacy numpy rng. Passing ``completion_table``
    (built by :func:`repro.fed.engine.fedbuff_completion_table` from the
    same seed — the "seed bridge") makes the device algorithm consume the
    EXACT legacy draws, pinning it bit-for-bit against :class:`FedBuff`
    (equivalence test in ``tests/test_engine.py``).

    Key semantics match the python class: the FIRST ``round`` key seeds the
    event stream (model-step, quantizer, and duration randomness all derive
    from the carried ``jkey``/table from then on) and later round keys are
    ignored — vary the seeding key, not later keys, to get an independent
    event stream. Determinism given ``init`` + the first key holds, and a
    scanned run is bit-for-bit the eager run.
    """
    completion_table: Optional[np.ndarray] = None
    client_mesh: Any = None             # shard the store's client axis

    def __post_init__(self):
        super().__post_init__()
        # stateful codecs degrade to their stateless encode here; the
        # python 'fedbuff' threads real per-client error feedback
        self._lam_j = jnp.asarray(self.lam)
        self._table_j = (jnp.asarray(self.completion_table, jnp.float32)
                         if self.completion_table is not None else None)

    # ------------------------------------------------------------------
    def init(self, params0) -> FedBuffDeviceState:
        server = tree_flatten_vector(params0)
        n = self.fed.n_clients
        pop = build_population(self.fed, n, lam=self.lam,
                               start=jnp.tile(server[None], (n, 1)),
                               occ=jnp.zeros((n,), jnp.int32))
        if self.client_mesh is not None:
            pop = shard_population(pop, self.client_mesh)
        return FedBuffDeviceState(
            server=server, pop=pop, queue=ring_init(n),
            sim_time=jnp.zeros(()), t=jnp.zeros((), jnp.int32),
            bits_up=jnp.zeros(()), bits_down=jnp.zeros(()),
            jkey=jax.random.PRNGKey(0), live=jnp.zeros((), bool))

    def _duration(self, kt, i, occ_i, lam_i):
        """Client i's next K-step duration: seed-bridge table lookup when
        pinned, else a device Gamma(K, 1/λ_i) draw. A table exhausted
        mid-simulation (more completions than the bridge replayed) poisons
        the clock with NaN instead of silently clamping the gather — an
        un-pinned event stream must be loud, not approximately right."""
        if self._table_j is not None:
            return jnp.where(occ_i < self._table_j.shape[1],
                             self._table_j[i, occ_i], jnp.nan)
        return completion_time_device(kt, self.fed.local_steps, lam_i)

    def _seeded(self, state: FedBuffDeviceState, key):
        """First-round seeding: initial completion draws for every client
        (table column 0 under the bridge, device draws otherwise)."""
        n = self.fed.n_clients
        if self._table_j is not None:
            times = self._table_j[:, 0]
        else:
            kts = jax.random.split(jax.random.fold_in(key, 0), n)
            times = jax.vmap(completion_time_device,
                             in_axes=(0, None, 0))(
                kts, self.fed.local_steps, state.pop.rows["lam"])
        queue = RingBuffer(times=times.astype(jnp.float32),
                           clients=jnp.arange(n, dtype=jnp.int32))
        return queue, jnp.ones((n,), jnp.int32), key

    # ------------------------------------------------------------------
    def device_round(self, state: FedBuffDeviceState, data, key):
        """One server update (one buffer flush) = a scan over exactly
        ``buffer_size`` completion events, fully on device."""
        fed = self.fed
        Z, d = self.buffer_size, self.d
        lam_row = state.pop.rows["lam"]
        queue, occ, jkey = jax.lax.cond(
            state.live,
            lambda: (state.queue, state.occ, state.jkey),
            lambda: self._seeded(state, key))

        def completion(carry, z):
            queue, occ, jkey, server, start, t_last, buffer, errs = carry
            queue, t_now, i = ring_pop(queue)
            jkey, sub = jax.random.split(jkey)
            delta = self._local(start[i], jax.tree_util.tree_map(
                lambda a: a[i], data), sub)
            rel = jnp.zeros(())
            if self._up_compressed:
                jkey, qk = jax.random.split(jkey)
                msg = self.codec_up.encode(
                    qk, delta, jnp.linalg.norm(delta) + 1e-12)
                dq = self.codec_up.decode(qk, msg, jnp.zeros_like(delta))
                rel = (jnp.linalg.norm(dq - delta)
                       / (jnp.linalg.norm(delta) + 1e-12))
                delta = dq
            buffer = buffer.at[z].set(delta)
            errs = errs.at[z].set(rel)
            # the buffer starts empty every protocol round, so the flush
            # lands on the Z-th completion — same mean-of-stack as legacy
            server = jax.lax.cond(
                z == Z - 1,
                lambda s: s - self.server_lr * jnp.mean(buffer, 0),
                lambda s: s, server)
            if self._down_identity:
                restart = server
            else:
                jkey, dk = jax.random.split(jkey)
                hint_dn = jnp.linalg.norm(server - start[i]) + 1e-12
                msg_dn = self.codec_down.encode(dk, server, hint_dn)
                restart = self.codec_down.decode(dk, msg_dn, start[i])
            start = start.at[i].set(restart)
            if self._table_j is None:
                jkey, kt = jax.random.split(jkey)
            else:
                kt = jkey   # bridge mode consumes no extra key (numpy rng
            #               # drew the durations in the legacy stream)
            dur = self._duration(kt, i, occ[i], lam_row[i])
            occ = occ.at[i].add(1)
            queue = ring_push(queue, t_now + dur, i)
            return (queue, occ, jkey, server, start, t_now, buffer,
                    errs), None

        carry0 = (queue, occ, jkey, state.server, state.start,
                  state.sim_time, jnp.zeros((Z, d)), jnp.zeros((Z,)))
        (queue, occ, jkey, server, start, t_now, _, errs), _ = jax.lax.scan(
            completion, carry0, jnp.arange(Z))

        # wire accounting by the per-direction codecs
        bits_up = jnp.asarray(Z * self.codec_up.message_bits(d), jnp.float32)
        bits_down = jnp.asarray(Z * self.codec_down.message_bits(d),
                                jnp.float32)
        new_time = t_now.astype(jnp.float32)
        new_state = FedBuffDeviceState(
            server=server,
            pop=with_rows(state.pop, start=start, occ=occ),
            queue=queue, sim_time=new_time, t=state.t + 1,
            bits_up=state.bits_up + bits_up,
            bits_down=state.bits_down + bits_down,
            jkey=jkey, live=jnp.ones((), bool))
        metrics = {
            "sim_time": new_time,
            "round_time": new_time - state.sim_time,
            "bits_up": bits_up,
            "bits_down": bits_down,
            "h_steps_mean": jnp.asarray(fed.local_steps, jnp.float32),
            "quant_err": (jnp.mean(errs) if self._up_compressed
                          else jnp.zeros(())),
            "buffer_flushes": jnp.ones(()),
        }
        return new_state, metrics

    @partial(jax.jit, static_argnums=0)
    def round(self, state: FedBuffDeviceState, data, key):
        return self.device_round(state, data, key)

    def eval_params(self, state: FedBuffDeviceState):
        return tree_unflatten_vector(self.template, state.server)

    # the legacy event loop belongs to the python implementation only
    run = None
