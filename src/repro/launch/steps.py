"""Distributed step builders: QuAFL train_step, prefill_step, serve_step.

The QuAFL mapping onto the mesh (DESIGN.md §3):
  * client_dp — client replicas stacked on a leading 'clients' axis sharded
    over the mesh 'data' axis (one divergent replica per data slice, tensor
    parallel over 'model' inside).
  * cohort    — one client per POD (giant architectures): parameters are
    FSDP-sharded over data×model; on the single-pod mesh n_slots=1 and QuAFL
    runs its s=1 instance (server + one cohort, still fully quantized).

train_step executes ONE server round of Algorithm 1: every client slot runs
up to K masked local SGD steps on its own microbatch stream, both directions
of the exchange are lattice-quantized, and the (s+1)-averaging preserves the
model mean. Asynchrony: each slot draws H_i ~ min(K, Poisson(λ_i·Δt)) inside
the step (paper App. B.1 equivalence).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compression.codecs import resolve_codec
from repro.compression.transports import transport_for_mode
from repro.configs.base import FedConfig, ModelConfig, ShapeConfig
from repro.core.quafl import client_speeds
from repro.core.transport import leaf_dist, tree_decode, tree_encode
from repro.launch.specs import (abstract_cache, enc_len_for, input_axes,
                                input_specs)
from repro.models.model import (abstract_lm, decode_step, forward, init_cache,
                                lm_loss)
from repro.sharding.rules import pspec_for, rules_for_mode

# architectures too large for per-data-slice client replicas get cohort mode
FED_MODE: Dict[str, str] = {
    "llama4-scout-17b-a16e": "cohort",
    "deepseek-v2-236b": "cohort",
    "jamba-1.5-large-398b": "cohort",
    "llava-next-34b": "cohort",
}


def fed_mode_for(arch_name: str) -> str:
    return FED_MODE.get(arch_name, "client_dp")


class TrainState(NamedTuple):
    server: Dict[str, Any]     # X_t
    clients: Dict[str, Any]    # X^i, leaves have a leading (n_slots,) axis
    t: jnp.ndarray


def n_slots_for(mesh, fed_mode: str) -> int:
    if fed_mode == "cohort":
        return int(mesh.shape.get("pod", 1))
    return int(mesh.shape["data"])


# ---------------------------------------------------------------------------
# abstract state + shardings
# ---------------------------------------------------------------------------

def abstract_train_state(cfg: ModelConfig, mesh, fed_mode: str):
    """(state spec tree, state shardings) for the dry-run."""
    spec, axes = abstract_lm(cfg)
    n = n_slots_for(mesh, fed_mode)
    rules = rules_for_mode(fed_mode)
    cl_spec = {k: jax.ShapeDtypeStruct((n,) + tuple(v.shape), v.dtype)
               for k, v in spec.items()}
    cl_axes = {k: ("clients",) + tuple(v) for k, v in axes.items()}
    srv_sh = {k: NamedSharding(mesh, pspec_for(v.shape, axes[k], rules, mesh))
              for k, v in spec.items()}
    cl_sh = {k: NamedSharding(mesh, pspec_for(cl_spec[k].shape, cl_axes[k],
                                              rules, mesh))
             for k in spec}
    state = TrainState(server=spec, clients=cl_spec,
                       t=jax.ShapeDtypeStruct((), jnp.int32))
    shardings = TrainState(server=srv_sh, clients=cl_sh,
                           t=NamedSharding(mesh, P()))
    return state, shardings


def init_train_state(cfg: ModelConfig, key, n_slots: int) -> TrainState:
    from repro.models.model import init_lm
    params, _ = init_lm(cfg, key)
    clients = {k: jnp.broadcast_to(v[None], (n_slots,) + v.shape)
               for k, v in params.items()}
    return TrainState(server=params, clients=clients,
                      t=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# train step (one QuAFL round)
# ---------------------------------------------------------------------------

def build_train_step(cfg: ModelConfig, fed: FedConfig, mesh, shape: ShapeConfig,
                     *, fed_mode: str = None, transport: str = None,
                     quantized: bool = True, remat: bool = True):
    """Returns (train_step, state_spec, in_shardings tuple)."""
    fed_mode = fed_mode or fed_mode_for(cfg.name)
    transport = transport or fed.transport
    n_slots = n_slots_for(mesh, fed_mode)
    rules = rules_for_mode(fed_mode)
    K, lr = fed.local_steps, fed.lr
    # per-direction codecs (repro.compression.codecs): the legacy
    # fed.quantizer map by default, any registry codec via fed.codec_up /
    # codec_down; `quantized=False` forces the uncompressed identity pair
    if quantized:
        quant_up = resolve_codec(None, fed, direction="up")
        quant_down = resolve_codec(None, fed, direction="down")
    else:
        quant_up = resolve_codec("identity", fed, direction="up")
        quant_down = resolve_codec("identity", fed, direction="down")
    # stateful codecs degrade to their stateless encode on the mesh path
    # (no per-client residual buffers in the train state)

    lam = client_speeds(fed, n_slots) if n_slots > 1 else np.array(
        [fed.lam_fast], np.float32)
    H = np.minimum(K, np.maximum(lam * (fed.swt + fed.sit), 1e-3))
    eta_i = ((H.min() / H) if fed.weighted else np.ones(n_slots)).astype(
        np.float32)

    def local_round(cp, toks, fe, h_i, key):
        """One client slot: up to K masked local steps. toks: (K, b, t)."""
        def loss_fn(p, batch):
            loss, _ = lm_loss(cfg, p, batch, remat=remat)
            return loss

        def step(p, q):
            batch = {"tokens": toks[q]}
            if fe is not None:
                batch["frontend"] = fe[q]
            g = jax.grad(loss_fn)(p, batch)
            act = (q < h_i).astype(jnp.float32)
            p = {k: (p[k] - lr * act * g[k].astype(p[k].dtype)) for k in p}
            return p, None

        pK, _ = jax.lax.scan(step, cp, jnp.arange(K))
        # Y = X - η·η_i·h̃ = (1-η_i)·X + η_i·X_K   (h̃ = (X - X_K)/η)
        return pK

    # vmap over client slots keeps the HLO one-body-sized; the MoE archs run
    # in cohort mode (n_slots ∈ {1, 2}) and use an unrolled loop instead, so
    # lax.ragged_dot never needs a batching rule.
    unroll_slots = (n_slots <= 2) or (cfg.moe is not None)
    # Pin the vmapped client axis to the mesh 'data' axis INSIDE the grad
    # scan too — without this GSPMD replicates per-client grads on every
    # device (§Perf iteration 2: dominant memory+collective term).
    spmd_axis = "data" if (fed_mode == "client_dp" and
                           mesh.shape.get("data", 1) > 1) else None

    def vmap_slots(fn, in_axes=0):
        return jax.vmap(fn, in_axes=in_axes, spmd_axis_name=spmd_axis)

    def slot_progress(cp_i, toks_i, fe_i, h_i, eta, key_i):
        pK = local_round(cp_i, toks_i, fe_i, h_i, key_i)
        # Y = X − η·η_i·h̃ = (1−η_i)·X + η_i·X_K
        Y_i = {k: ((1.0 - eta) * cp_i[k].astype(jnp.float32)
                   + eta * pK[k].astype(jnp.float32)).astype(cp_i[k].dtype)
               for k in cp_i}
        return Y_i, leaf_dist(Y_i, cp_i)

    def slot_encode(Y_i, hints_i, key_i):
        return tree_encode(quant_up, key_i, Y_i, hints_i)

    def slot_decode_up(msgs_i, key_i, server):
        return tree_decode(quant_up, key_i, msgs_i, server)

    def slot_update(cp_i, Y_i, k_srv, msg_srv, denom):
        QX_i = tree_decode(quant_down, k_srv, msg_srv, cp_i)
        return {k: (QX_i[k].astype(jnp.float32) / denom
                    + (denom - 1) * Y_i[k].astype(jnp.float32) / denom
                    ).astype(cp_i[k].dtype) for k in cp_i}

    def train_step(state: TrainState, batch, key_raw):
        key = jax.random.wrap_key_data(key_raw)
        k_h, k_q, k_loc = jax.random.split(key, 3)
        toks = batch["tokens"]                   # (n_slots, K, b, t)
        fe = batch.get("frontend")
        h_steps = jnp.minimum(
            jax.random.poisson(k_h, jnp.asarray(lam) * (fed.swt + fed.sit),
                               (n_slots,)), K).astype(jnp.int32)
        etas = jnp.asarray(eta_i)
        loc_keys = jax.random.split(k_loc, n_slots)
        q_keys = jax.random.split(jax.random.fold_in(k_q, 1), n_slots)
        denom = n_slots + 1

        def sl(tree, i):
            return {k: v[i] for k, v in tree.items()}

        if unroll_slots:
            pieces = [slot_progress(sl(state.clients, i), toks[i],
                                    fe[i] if fe is not None else None,
                                    h_steps[i], etas[i], loc_keys[i])
                      for i in range(n_slots)]
            Ys = {k: jnp.stack([p[0][k] for p in pieces], 0)
                  for k in state.server}
            hints_up = {k: jnp.stack([p[1][k] for p in pieces], 0)
                        for k in state.server}
        else:
            Ys, hints_up = vmap_slots(
                lambda cp, tk, f, h, e, kk: slot_progress(cp, tk, f, h, e, kk)
            )(state.clients, toks, fe, h_steps, etas, loc_keys) \
                if fe is not None else vmap_slots(
                lambda cp, tk, h, e, kk: slot_progress(cp, tk, None, h, e, kk)
            )(state.clients, toks, h_steps, etas, loc_keys)

        # ---- shard-local exchange (§Perf): whole exchange in shard_map ----
        if transport in ("shard_local", "shard_local_codes",
                         "shard_local_rs") and quantized:
            from repro.core.exchange_local import make_shardlocal_exchange
            rules_ = rules_for_mode(fed_mode)
            spec_, axes_ = abstract_lm(cfg)
            srv_ps = {k: pspec_for(v.shape, axes_[k], rules_, mesh)
                      for k, v in spec_.items()}
            cl_ps = {k: pspec_for((n_slots,) + tuple(v.shape),
                                  ("clients",) + tuple(axes_[k]), rules_,
                                  mesh) for k, v in spec_.items()}
            client_axis = "pod" if fed_mode == "cohort" else "data"
            ex = make_shardlocal_exchange(
                quant_up, quant_down, mesh, srv_ps, cl_ps, client_axis,
                n_slots, transport=transport_for_mode(transport))
            server_new, clients_new, qerr = ex(
                state.server, state.clients, Ys,
                jax.random.key_data(jax.random.fold_in(k_q, 3)))
            new_state = TrainState(server=server_new, clients=clients_new,
                                   t=state.t + 1)
            return new_state, {
                "h_steps_mean": jnp.mean(h_steps.astype(jnp.float32)),
                "quant_err_sq": qerr}

        # ---- client -> server: Enc(Y^i), decoded against X_t -------------
        msgs_up = vmap_slots(slot_encode)(Ys, hints_up, q_keys)
        if transport == "code_allgather" and quantized:
            repl = NamedSharding(mesh, P())
            # replicate every message leaf (codes, scales, indices, ...) so
            # any codec's wire format rides this transport
            msgs_up = {k: jax.tree_util.tree_map(
                lambda a: jax.lax.with_sharding_constraint(a, repl), m)
                for k, m in msgs_up.items()}
        QYs = jax.vmap(slot_decode_up, in_axes=(0, 0, None),
                       spmd_axis_name=(None if transport == "code_allgather"
                                       else spmd_axis))(
            msgs_up, q_keys, state.server)

        server_new = {
            k: ((state.server[k].astype(jnp.float32)
                 + jnp.sum(QYs[k].astype(jnp.float32), 0)) / denom
                ).astype(state.server[k].dtype)
            for k in state.server}

        # ---- server -> clients: ONE Enc(X_t), per-client decode ----------
        hints_down = {
            k: 2.0 * jnp.max(jax.vmap(
                lambda q: jnp.linalg.norm(
                    (q - state.server[k]).astype(jnp.float32).ravel()))(
                QYs[k]))
            for k in state.server}
        k_srv = jax.random.fold_in(k_q, n_slots + 7)
        msg_srv = tree_encode(quant_down, k_srv, state.server, hints_down)

        if unroll_slots:
            cls = [slot_update(sl(state.clients, i), sl(Ys, i), k_srv,
                               msg_srv, denom) for i in range(n_slots)]
            clients_new = {k: jnp.stack([c[k] for c in cls], 0)
                           for k in state.server}
        else:
            clients_new = jax.vmap(slot_update,
                                   in_axes=(0, 0, None, None, None),
                                   spmd_axis_name=spmd_axis)(
                state.clients, Ys, k_srv, msg_srv, denom)

        qerr = sum(jnp.sum(jnp.square((QYs[k] - Ys[k]).astype(jnp.float32)))
                   for k in state.server) / n_slots

        new_state = TrainState(server=server_new, clients=clients_new,
                               t=state.t + 1)
        metrics = {"h_steps_mean": jnp.mean(h_steps.astype(jnp.float32)),
                   "quant_err_sq": qerr}
        return new_state, metrics

    state_spec, state_sh = abstract_train_state(cfg, mesh, fed_mode)
    in_ax = input_axes(cfg, shape)
    batch_sh = {k: NamedSharding(
        mesh, pspec_for(v.shape, in_ax[k], rules, mesh))
        for k, v in input_specs(cfg, shape, n_slots=n_slots,
                                local_steps=K).items()}
    key_sh = NamedSharding(mesh, P())
    return train_step, state_spec, (state_sh, batch_sh, key_sh)


# ---------------------------------------------------------------------------
# prefill / serve steps (inference of the server model)
# ---------------------------------------------------------------------------

def build_prefill_step(cfg: ModelConfig, mesh, shape: ShapeConfig):
    rules = rules_for_mode("client_dp")
    enc = enc_len_for(shape) if cfg.encdec else 0

    def prefill_step(params, batch):
        cache0 = init_cache(cfg, shape.global_batch, shape.seq_len,
                            abstract=False, enc_len=enc)
        logits, cache, _ = forward(cfg, params, batch, cache=cache0,
                                   write_pos=0)
        return logits[:, -1], cache

    spec, axes = abstract_lm(cfg)
    p_sh = {k: NamedSharding(mesh, pspec_for(v.shape, axes[k], rules, mesh))
            for k, v in spec.items()}
    in_ax = input_axes(cfg, shape)
    b_sh = {k: NamedSharding(mesh, pspec_for(v.shape, in_ax[k], rules, mesh))
            for k, v in input_specs(cfg, shape).items()}
    return prefill_step, spec, (p_sh, b_sh)


def build_serve_step(cfg: ModelConfig, mesh, shape: ShapeConfig):
    """One-token decode against a seq_len-deep cache (decode shapes)."""
    rules = rules_for_mode("client_dp")

    def serve_step(params, cache, token, pos):
        logits, cache = decode_step(cfg, params, token, pos, cache)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    spec, axes = abstract_lm(cfg)
    p_sh = {k: NamedSharding(mesh, pspec_for(v.shape, axes[k], rules, mesh))
            for k, v in spec.items()}
    cache_spec, c_axes = abstract_cache(cfg, shape)
    c_sh = {k: NamedSharding(mesh, pspec_for(v.shape, c_axes[k], rules, mesh))
            for k, v in cache_spec.items()}
    tok_sh = NamedSharding(mesh, pspec_for((shape.global_batch, 1),
                                           ("batch", None), rules, mesh))
    pos_sh = NamedSharding(mesh, P())
    return serve_step, spec, cache_spec, (p_sh, c_sh, tok_sh, pos_sh)
