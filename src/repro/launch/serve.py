"""Serving driver: batched requests against a (reduced) model via the
ServeEngine. Demonstrates the decode path the decode_32k/long_500k dry-run
shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --requests 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.model import init_lm
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving demo not wired in this CLI")
    key = jax.random.PRNGKey(args.seed)
    params, _ = init_lm(cfg, key)
    eng = ServeEngine(cfg, params, max_batch=args.max_batch, max_seq=128,
                      temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        eng.submit(Request(prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run(key)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
