"""Serving driver: batched requests against a (reduced) model via the
ServeEngine. Demonstrates the decode path the decode_32k/long_500k dry-run
shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --requests 12

With ``--from-algo NAME`` the served weights are the ``eval_params`` of a
short federated run of that registry algorithm (quafl, fedavg, ...) instead
of a fresh init — serving is inference of the federated result, and the
unified protocol makes any algorithm's outcome servable the same way:

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
      --from-algo quafl --algo-rounds 5 --requests 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models.model import init_lm
from repro.serving import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--from-algo", default="",
                    help="registry algorithm whose eval_params to serve "
                         "(quafl|fedavg|fedbuff|sequential|...)")
    ap.add_argument("--algo-rounds", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.encdec:
        raise SystemExit("enc-dec serving demo not wired in this CLI")
    key = jax.random.PRNGKey(args.seed)
    params, _ = init_lm(cfg, key)
    if args.from_algo:
        from functools import partial

        from repro.configs.base import FedConfig
        from repro.data.synthetic import federated_token_task
        from repro.fed import make_algorithm, simulate
        from repro.models.model import lm_loss

        fed = FedConfig(n_clients=4, s=4, local_steps=2, lr=0.05,
                        quantizer="lattice")
        pool, batch, seq = 8, 2, 32
        data, batch_fn = federated_token_task(args.seed, fed.n_clients,
                                              pool, batch, seq,
                                              cfg.vocab_size)

        alg = make_algorithm(args.from_algo, fed, loss_fn=partial(lm_loss,
                                                                  cfg),
                             template=params, batch_fn=batch_fn)
        trace = simulate(alg, params, data, jax.random.fold_in(key, 1),
                         rounds=args.algo_rounds, eval_every=0)
        print(f"serving eval_params of a {args.from_algo} run "
              f"({trace.rounds} rounds, "
              f"sim_t={float(trace.final_state.sim_time):.0f})")
        eng = ServeEngine.from_algorithm(cfg, alg, trace.final_state,
                                         max_batch=args.max_batch,
                                         max_seq=128,
                                         temperature=args.temperature)
    else:
        eng = ServeEngine(cfg, params, max_batch=args.max_batch, max_seq=128,
                          temperature=args.temperature)
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, plen).tolist()
        eng.submit(Request(prompt=prompt, max_new_tokens=args.max_new))
    t0 = time.time()
    done = eng.run(key)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, r in enumerate(done[:4]):
        print(f"  req{i}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
