"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state. The dry-run launcher sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` BEFORE any jax
import; smoke tests and benchmarks see the single real CPU device.
"""
from __future__ import annotations

import jax  # noqa: F401  (device state touched lazily)

from repro.utils.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pods: int = 0):
    """Small mesh over however many (host) devices exist — used by the
    sharding unit tests with --xla_force_host_platform_device_count=8."""
    if pods:
        return make_mesh((pods, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
