"""Roofline analysis from the compiled dry-run artifact.

Three terms, per (arch × shape × mesh), all in seconds-per-step *per chip*
(the post-SPMD module is per-partition, so cost_analysis numbers are already
per device):

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = Σ_k bytes_k · ring_factor_k / ICI (~50 GB/s/link; 1 link)

collective bytes are NOT in cost_analysis: we parse the post-optimization
HLO (``compiled.as_text()``) and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, with standard
ring factors (all-reduce counts 2×: reduce-scatter + all-gather phases).
"""
from __future__ import annotations

import re
from typing import Dict

import numpy as np

# TPU v5e (per brief)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RING_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# e.g.:  %all-reduce.5 = f32[16,1024]{1,0} all-reduce(...)
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """kind -> {'bytes': total result bytes, 'count': n ops}."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:       # async pair: count only the start
            continue
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = n * _DTYPE_BYTES[dtype]
        slot = out.setdefault(kind, {"bytes": 0.0, "count": 0})
        slot["bytes"] += b
        slot["count"] += 1
    return out


def collective_seconds(coll: Dict[str, Dict[str, float]]) -> float:
    return sum(v["bytes"] * RING_FACTOR.get(k, 1.0) / ICI_BW
               for k, v in coll.items())


def roofline(flops: float, bytes_accessed: float,
             coll: Dict[str, Dict[str, float]]) -> Dict[str, float]:
    t_c = flops / PEAK_FLOPS
    t_m = bytes_accessed / HBM_BW
    t_x = collective_seconds(coll)
    terms = {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


# ---------------------------------------------------------------------------
# MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)
# ---------------------------------------------------------------------------

def active_params(cfg) -> float:
    """Activated parameter count (expert leaves scaled by top_k/E)."""
    from repro.models.model import abstract_lm
    spec, axes = abstract_lm(cfg)
    total = 0.0
    for k, v in spec.items():
        n = float(np.prod(v.shape))
        if axes[k] and "experts" in axes[k] and cfg.moe and "router" not in k:
            n *= cfg.moe.top_k / cfg.moe.n_experts
        total += n
    return total


def tokens_per_step(cfg, shape, local_steps: int, n_slots: int) -> float:
    if shape.kind == "train":
        b_local = max(shape.global_batch // n_slots, 1)
        return n_slots * local_steps * b_local * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one token per sequence


def model_flops(cfg, shape, local_steps: int, n_slots: int) -> float:
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd = 3x fwd
    return 2.0 * active_params(cfg) * tokens_per_step(
        cfg, shape, local_steps, n_slots) * mult
