"""The mesh-sharded QuAFL train step behind the unified FedAlgorithm API.

Historically ``launch/train.py --algo spmd`` drove ``build_train_step``
through a bespoke loop with its own state and ad-hoc metrics — the one
execution path outside the protocol (ROADMAP: "SPMD path onto the unified
API"). :class:`SpmdAlgorithm` closes that gap: the distributed step
(clients living on mesh data slices, exchange running as mesh collectives)
becomes a registry algorithm (``make_algorithm("spmd", ..., cfg=...)``)
whose ``round`` emits the standardized metrics schema, so SPMD runs land in
the same ``simulate()`` Trace format as every simulator algorithm — and,
because the round is pure traced code over a pytree state, the scanned
engine (``simulate(..., scan_chunk=K)``) applies to distributed training
too.

Mapping notes:
  * one client per mesh slot — ``n_slots`` comes from the mesh (the 'data'
    axis, or 'pod' in cohort mode), NOT from ``fed.n_clients``; ``data``
    (the stacked per-client token pools from
    :func:`repro.data.synthetic.federated_token_task`) must provide at
    least ``n_slots`` clients and the first ``n_slots`` are used.
  * the clock observation is QuAFL's (the step IS Algorithm 1): every round
    lasts ``swt + sit`` simulated seconds; H_i is drawn inside the step.
  * bit accounting is QuAFL's: s quantized uplink messages plus ONE
    downlink broadcast Enc(X_t) per round (``tree_bits`` over the param
    tree), plus the transport's gathered side-channel / coded-re-gather
    payload (``Transport.extra_bits_down`` — the (n-1) extra γ/levels f32
    rows a code all-gather moves, or the scatter-resident coded
    redistribution of the fused reduce_scatter) charged into ``bits_down``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.compression.codecs import IdentityCodec, resolve_codec
from repro.configs.base import FedConfig, ModelConfig, ShapeConfig
from repro.core.transport import tree_bits
from repro.launch.steps import (TrainState, build_train_step, fed_mode_for,
                                n_slots_for)


class SpmdState(NamedTuple):
    """Mesh train state + the clock/bit counters the schema requires."""
    train: TrainState
    sim_time: jnp.ndarray
    bits_up: jnp.ndarray
    bits_down: jnp.ndarray

    @property
    def bits_sent(self):
        return self.bits_up + self.bits_down


@dataclass(eq=False)
class SpmdAlgorithm:
    """Registry name ``"spmd"``. Requires ``cfg`` (the ModelConfig whose
    params pytree ``init``/``round`` operate on); ``mesh`` defaults to a
    single-device (1, 1) data×model mesh, which is the CPU-CI instance of
    the same program a pod runs via GSPMD."""
    fed: FedConfig
    template: Any                      # params pytree (shapes only)
    cfg: ModelConfig = None
    mesh: Any = None
    batch: int = 2                     # per-client microbatch rows
    seq: int = 32
    fed_mode: Optional[str] = None
    transport: Optional[str] = None
    remat: bool = False

    def __post_init__(self):
        if self.cfg is None:
            raise ValueError("SpmdAlgorithm needs cfg=<ModelConfig> (pass "
                             "it through make_algorithm('spmd', ..., "
                             "cfg=...))")
        if self.cfg.frontend:
            raise NotImplementedError("spmd registry path covers token-only "
                                      "architectures (no frontend batches)")
        if self.mesh is None:
            from repro.utils.compat import make_mesh
            self.mesh = make_mesh((1, 1), ("data", "model"))
        self.fed_mode = self.fed_mode or fed_mode_for(self.cfg.name)
        self.n_slots = n_slots_for(self.mesh, self.fed_mode)
        shape = ShapeConfig("spmd", self.seq, self.batch * self.n_slots,
                            "train")
        # per-direction codecs drive both the step build and the metrics'
        # wire accounting (bits computed BY the codec, per leaf)
        self.codec_up = resolve_codec(None, self.fed, direction="up")
        self.codec_down = resolve_codec(None, self.fed, direction="down")
        self.quant = self.codec_up   # legacy accessor
        quantized = not (isinstance(self.codec_up, IdentityCodec)
                         and isinstance(self.codec_down, IdentityCodec))
        with self.mesh:
            self._step, _, (self._state_sh, _, _) = build_train_step(
                self.cfg, self.fed, self.mesh, shape,
                fed_mode=self.fed_mode, transport=self.transport,
                quantized=quantized, remat=self.remat)
        self._bits_up_msg = tree_bits(self.codec_up, self.template)
        self._bits_down_msg = tree_bits(self.codec_down, self.template)
        # the transport's redistribution payload (gathered γ/levels rows,
        # or the fused reduce_scatter's coded shard re-gather) is downlink
        # traffic the per-message codec math cannot see — charge it per
        # leaf at the mesh's slot count (0 on the (1,1) CI mesh)
        from repro.compression.transports import transport_for_mode
        tr = transport_for_mode(self.transport or self.fed.transport)
        self._extra_bits_down = 0
        if tr is not None and hasattr(tr, "extra_bits_down"):
            self._extra_bits_down = sum(
                tr.extra_bits_down(self.codec_up, self.codec_down,
                                   int(v.size), self.n_slots)
                for v in jax.tree_util.tree_leaves(self.template))

    # ------------------------------------------------------------------
    def init(self, params0) -> SpmdState:
        # fresh buffers, NOT views of params0: the eager round donates its
        # input state, so the state must never alias the caller's params
        server = {k: jnp.array(v) for k, v in params0.items()}
        clients = {k: jnp.broadcast_to(v[None], (self.n_slots,) + v.shape)
                   for k, v in params0.items()}
        train = TrainState(server=server, clients=clients,
                           t=jnp.zeros((), jnp.int32))
        # place the state with the build shardings so GSPMD lays clients
        # out along the mesh data axis (on the (1,1) CI mesh this is a
        # no-op; on a pod it is what distributes the replicas)
        train = jax.device_put(train, self._state_sh)
        return SpmdState(train=train, sim_time=jnp.zeros(()),
                         bits_up=jnp.zeros(()), bits_down=jnp.zeros(()))

    def device_round(self, state: SpmdState, data, key):
        """One mesh round: sample each slot's (K, b) microbatches from its
        token pool, run the distributed step, standardize the metrics."""
        fed = self.fed
        n, K = self.n_slots, fed.local_steps
        k_b, k_r = jax.random.split(key)
        pool = data["tokens"].shape[1]
        idx = jax.random.randint(k_b, (n, K, self.batch), 0, pool)
        toks = jax.vmap(lambda p, ix: p[ix])(data["tokens"][:n], idx)
        train, m = self._step(state.train, {"tokens": toks},
                              jax.random.key_data(k_r))

        # QuAFL bit accounting: s uplink messages, one downlink broadcast,
        # plus the transport's gathered side-channel rows / coded re-gather
        bits_up = jnp.asarray(n * self._bits_up_msg, jnp.float32)
        bits_down = jnp.asarray(self._bits_down_msg
                                + self._extra_bits_down, jnp.float32)
        dt = fed.swt + fed.sit
        new_time = state.sim_time + dt
        # schema quant_err: RMS decode error relative to the server norm
        # (the step measures the squared error summed over leaves)
        srv_sq = sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                     for v in train.server.values())
        rel = jnp.sqrt(m["quant_err_sq"]) / (jnp.sqrt(srv_sq) + 1e-12)
        metrics = {
            "sim_time": new_time,
            "round_time": jnp.asarray(dt, jnp.float32),
            "bits_up": bits_up,
            "bits_down": bits_down,
            "h_steps_mean": m["h_steps_mean"],
            "quant_err": rel,
            "quant_err_sq": m["quant_err_sq"],
        }
        return SpmdState(train=train, sim_time=new_time,
                         bits_up=state.bits_up + bits_up,
                         bits_down=state.bits_down + bits_down), metrics

    # the eager round donates the incoming state (the legacy driver loop's
    # donate_argnums, folded into the protocol entry point); the scanned
    # engine drives device_round instead, where scan carries the buffers
    @partial(jax.jit, static_argnums=0, donate_argnums=1)
    def round(self, state: SpmdState, data, key):
        return self.device_round(state, data, key)

    def eval_params(self, state: SpmdState):
        return state.train.server
