import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run profiler: print the top memory-traffic / collective contributors
for one (arch × shape) pair — the §Perf napkin-math tool.

  PYTHONPATH=src python -m repro.launch.profile_pair --arch deepseek-v2-236b \
      --shape prefill_32k
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.configs.base import FedConfig
from repro.launch.hlocost import top_contributors
from repro.launch.mesh import make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--transport", default="dequant_psum")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    fed = FedConfig(local_steps=2)
    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if shape.name == "long_500k":
        cfg = cfg.with_long_variant()

    # rebuild the lowered artifact (same path as dryrun.lower_pair)
    from repro.launch.steps import (build_prefill_step, build_serve_step,
                                    build_train_step, fed_mode_for,
                                    n_slots_for)
    from repro.launch.specs import input_specs
    fed_mode = fed_mode_for(args.arch)
    with mesh:
        if shape.kind == "train":
            step, state_spec, (st_sh, b_sh, k_sh) = build_train_step(
                cfg, fed, mesh, shape, fed_mode=fed_mode,
                transport=args.transport)
            batch = input_specs(cfg, shape, n_slots=n_slots_for(mesh, fed_mode),
                                local_steps=fed.local_steps)
            lowered = jax.jit(step, in_shardings=(st_sh, b_sh, k_sh)).lower(
                state_spec, batch, jax.ShapeDtypeStruct((2,), jnp.uint32))
        elif shape.kind == "prefill":
            step, p_spec, (p_sh, b_sh) = build_prefill_step(cfg, mesh, shape)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(
                p_spec, input_specs(cfg, shape))
        else:
            step, p_spec, c_spec, shs = build_serve_step(cfg, mesh, shape)
            ins = input_specs(cfg, shape)
            lowered = jax.jit(step, in_shardings=shs).lower(
                p_spec, c_spec, ins["token"], ins["pos"])
        text = lowered.compile().as_text()
    for r in top_contributors(text, args.top):
        print(f"{r['bytes']:.3e}B  x{r['mult']:g}  {r['op']:<14s} "
              f"{r['line'][:130]}")


if __name__ == "__main__":
    main()
