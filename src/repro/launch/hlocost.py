"""Trip-count-aware cost analysis of post-optimization (SPMD, per-partition)
HLO text.

``compiled.cost_analysis()`` visits each called computation ONCE, so a
``lax.scan`` over 48 layers under-counts FLOPs, bytes and (critically) the
TP collectives inside the loop body by 48x. This walker parses the HLO text,
builds the computation call graph, extracts while-loop trip counts from the
loop condition's comparison constant, and multiplies costs through.

Per computation we count:
  * flops        — dot ops: 2 · prod(out_shape) · contracted_size (operand
                   shapes resolved through a per-computation symbol table)
  * bytes        — operand + result bytes of top-level compute instructions
                   (HBM-traffic proxy; layout-only ops are skipped, fusions
                   count their operands/result once — matching XLA's own
                   "bytes accessed" convention)
  * collectives  — result bytes + op count per collective kind
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops whose results are layout/book-keeping, not memory traffic
_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "after-all", "partition-id", "replica-id", "domain",
             "opt-barrier"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->")
_TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
_LHS_RE = re.compile(r"^(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))"
                    r"\s+([\w\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _bytes_of(dtype: str, dims: List[int]) -> float:
    if dtype not in _DTYPE_BYTES:
        return 0.0
    n = 1
    for d in dims:
        n *= d
    return float(n * _DTYPE_BYTES[dtype])


def _shapes_in(seg: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(seg):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


@dataclass
class Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = field(default_factory=dict)
    whiles: List[Tuple[str, str, Optional[int]]] = field(default_factory=list)
    calls: List[str] = field(default_factory=list)
    cond_const: Optional[int] = None
    records: List[Tuple[str, float, float, str]] = field(
        default_factory=list)  # (op, bytes, flops, line snippet)


def _parse(text: str) -> Dict[str, Comp]:
    comps: Dict[str, Comp] = {}
    cur: Optional[Comp] = None
    symtab: Dict[str, List[Tuple[str, List[int]]]] = {}

    for raw in text.splitlines():
        if not raw:
            continue
        if not raw.startswith(" "):
            hm = _HEADER_RE.match(raw)
            if hm:
                cur = comps.setdefault(hm.group(2), Comp(hm.group(2)))
                symtab = {}
                if hm.group(1):
                    comps["__entry__"] = cur
            continue
        if cur is None:
            continue
        lm = _LHS_RE.match(raw.strip())
        if not lm:
            continue
        lhs, rhs = lm.group(1), lm.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        shapes_seg, op = om.group(1), om.group(2)
        shapes = _shapes_in(shapes_seg)
        symtab[lhs] = shapes
        res_bytes = sum(_bytes_of(d, dims) for d, dims in shapes)

        if op == "constant":
            mc = _CONST_RE.search(rhs)
            if mc and any(d in ("s32", "u32", "s64", "u64") and not dims
                          for d, dims in shapes):
                v = int(mc.group(1))
                if cur.cond_const is None or v > cur.cond_const:
                    cur.cond_const = v
            continue
        if op in _FREE_OPS:
            continue

        # operand resolution
        pm = re.search(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)", rhs[om.end(0):]
                       if False else rhs[len(shapes_seg):])
        operand_names: List[str] = []
        if pm:
            # post-optimization HLO references operands as '%name'; find them
            # directly — splitting on commas breaks inside layout annotations
            # like 'f32[8,64]{1,0}'.
            operand_names = re.findall(r"%([\w\.\-]+)", pm.group(1))
            if not operand_names:
                for tok in pm.group(1).split(","):
                    tok = tok.strip()
                    tm = re.match(r"%?([\w\.\-]+)$", tok)
                    if tm:
                        operand_names.append(tm.group(1))
        op_bytes = 0.0
        for nm in operand_names:
            for d, dims in symtab.get(nm, []):
                op_bytes += _bytes_of(d, dims)

        if op == "dot":
            out_elems = 1
            for _, dims in shapes:
                for d in dims:
                    out_elems *= d
            contracted = 1
            mlhs = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
            if mlhs and mlhs.group(1) and operand_names:
                lhs_shapes = symtab.get(operand_names[0], [])
                if lhs_shapes:
                    lhs_dims = lhs_shapes[0][1]
                    for ci in mlhs.group(1).split(","):
                        ci = int(ci)
                        if ci < len(lhs_dims):
                            contracted *= lhs_dims[ci]
            cur.flops += 2.0 * out_elems * contracted

        for kind in COLLECTIVES:
            if op == kind or op == kind + "-start":
                slot = cur.coll.setdefault(kind, {"bytes": 0.0, "count": 0})
                slot["bytes"] += res_bytes
                slot["count"] += 1
                break

        if op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", rhs)
            mc2 = re.search(r"condition=%?([\w\.\-]+)", rhs)
            mt = _TRIP_RE.search(rhs)
            if mb and mc2:
                cur.whiles.append((mb.group(1), mc2.group(1),
                                   int(mt.group(1)) if mt else None))
            continue  # body accounts for its own traffic
        if op in ("call", "conditional"):
            for m in re.finditer(r"(?:to_apply|calls)=%?([\w\.\-]+)", rhs):
                cur.calls.append(m.group(1))
            mbr = re.search(r"branch_computations=\{([^}]*)\}", rhs)
            if mbr:
                for nm in mbr.group(1).split(","):
                    cur.calls.append(nm.strip().lstrip("%"))
            continue
        # fusion/reduce/sort/etc: sub-computations are element-level lambdas —
        # do NOT recurse for bytes; the op line itself carries the traffic.
        cur.bytes += res_bytes + op_bytes
        fl_here = 0.0
        if op == "dot":
            fl_here = cur.flops  # records store cumulative; fixed below
        cur.records.append((op, res_bytes + op_bytes, fl_here,
                            raw.strip()[:160]))
    return comps


def top_contributors(text: str, k: int = 12) -> List[Dict]:
    """Top-k instructions by (trip-count-scaled) memory traffic."""
    comps = _parse(text)
    entry = comps.get("__entry__")
    if entry is None:
        return []
    # effective multiplier per computation
    mult: Dict[str, float] = {entry.name: 1.0}
    order = [entry.name]
    i = 0
    while i < len(order):
        c = comps[order[i]]
        m = mult[c.name]
        for callee in c.calls:
            if callee in comps:
                mult[callee] = mult.get(callee, 0.0) + m
                order.append(callee)
        for body, cond, known in c.whiles:
            trip = known if known is not None else (
                comps[cond].cond_const if cond in comps else None)
            trip = max(int(trip or 1), 1)
            if body in comps:
                mult[body] = mult.get(body, 0.0) + m * trip
                order.append(body)
        i += 1
        if i > 10000:
            break
    rows = []
    for name, m in mult.items():
        for op, by, _, line in comps[name].records:
            rows.append({"bytes": by * m, "op": op, "comp": name,
                         "mult": m, "line": line})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]


def analyze_hlo(text: str) -> Dict:
    comps = _parse(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "collectives": {}}
    memo: Dict[str, Tuple[float, float, Dict]] = {}

    def cost(name: str, stack=()) -> Tuple[float, float, Dict]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return 0.0, 0.0, {}
        c = comps[name]
        fl, by = c.flops, c.bytes
        coll = {k: dict(v) for k, v in c.coll.items()}
        for callee in c.calls:
            f2, b2, x2 = cost(callee, stack + (name,))
            fl += f2
            by += b2
            _merge(coll, x2, 1.0)
        for body, cond, known in c.whiles:
            trip = known if known is not None else (
                comps[cond].cond_const if cond in comps else None)
            trip = max(int(trip or 1), 1)
            f2, b2, x2 = cost(body, stack + (name,))
            fl += f2 * trip
            by += b2 * trip
            _merge(coll, x2, trip)
        memo[name] = (fl, by, coll)
        return memo[name]

    fl, by, coll = cost(entry.name)
    return {"flops": fl, "bytes": by, "collectives": coll}


def _merge(dst: Dict, src: Dict, mult: float):
    for k, v in src.items():
        slot = dst.setdefault(k, {"bytes": 0.0, "count": 0})
        slot["bytes"] += v["bytes"] * mult
        slot["count"] += v["count"] * mult
