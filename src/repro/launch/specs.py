"""ShapeDtypeStruct stand-ins for every model input (dry-run: weak-type
correct, shardable, no device allocation) and the cache-axes metadata used
for sharding the serving state."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import KIND_MAMBA, ModelConfig, ShapeConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models.model import init_cache


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def enc_len_for(shape: ShapeConfig) -> int:
    return min(4096, max(shape.seq_len // 8, 16))


def _train_text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.encdec:
        return seq_len // 2
    if cfg.frontend:
        return seq_len - cfg.n_frontend_tokens
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, n_slots: int = 1,
                local_steps: int = 1) -> Dict[str, jax.ShapeDtypeStruct]:
    """Stand-ins for the step function's data arguments."""
    act = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        b_local = max(shape.global_batch // n_slots, 1)
        t_text = _train_text_len(cfg, shape.seq_len)
        specs = {"tokens": sds((n_slots, local_steps, b_local, t_text),
                               jnp.int32)}
        if cfg.encdec:
            specs["frontend"] = sds(
                (n_slots, local_steps, b_local, shape.seq_len // 2,
                 cfg.d_model), act)
        elif cfg.frontend:
            specs["frontend"] = sds(
                (n_slots, local_steps, b_local, cfg.n_frontend_tokens,
                 cfg.d_model), act)
        return specs
    if shape.kind == "prefill":
        t_text = _train_text_len(cfg, shape.seq_len)
        specs = {"tokens": sds((shape.global_batch, t_text), jnp.int32)}
        if cfg.encdec:
            specs["frontend"] = sds(
                (shape.global_batch, shape.seq_len // 2, cfg.d_model), act)
        elif cfg.frontend:
            specs["frontend"] = sds(
                (shape.global_batch, cfg.n_frontend_tokens, cfg.d_model), act)
        return specs
    # decode
    return {"token": sds((shape.global_batch, 1), jnp.int32),
            "pos": sds((), jnp.int32)}


# ---------------------------------------------------------------------------
# data-argument logical axes (for in_shardings)
# ---------------------------------------------------------------------------

def input_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, tuple]:
    if shape.kind == "train":
        ax = {"tokens": ("clients", None, "batch_local", None)}
        if cfg.encdec or cfg.frontend:
            ax["frontend"] = ("clients", None, "batch_local", None, None)
        return ax
    if shape.kind == "prefill":
        ax = {"tokens": ("batch", None)}
        if cfg.encdec or cfg.frontend:
            ax["frontend"] = ("batch", None, None)
        return ax
    return {"token": ("batch", None), "pos": ()}


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig):
    """(cache spec tree, cache axes tree) for the decode shapes."""
    enc = enc_len_for(shape) if cfg.encdec else 0
    cache = init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True,
                       enc_len=enc)
    axes = cache_axes(cfg)
    return cache, axes


def cache_axes(cfg: ModelConfig) -> Dict[str, tuple]:
    """Flat dict of logical axes matching init_cache's paths."""
    def layer_axes(spec):
        ax = {}
        if spec.kind == KIND_MAMBA:
            for k, v in mam.mamba_cache_axes().items():
                ax[f"mamba/{k}"] = v
        elif spec.attn == "mla":
            for k, v in mla_mod.mla_cache_axes().items():
                ax[f"mla/{k}"] = v
        else:
            for k, v in attn_mod.attn_cache_axes(spec).items():
                ax[f"attn/{k}"] = v
        if cfg.encdec:
            ax["cross/k"] = ("batch", None, None, None)
            ax["cross/v"] = ("batch", None, None, None)
        return ax

    out = {}
    for i, spec in enumerate(cfg.prefix):
        for k, v in layer_axes(spec).items():
            out[f"pre/{i}/{k}"] = v
    for j, spec in enumerate(cfg.schedule):
        for k, v in layer_axes(spec).items():
            out[f"body/{j}/{k}"] = ("layers",) + tuple(v)
    return out
