"""End-to-end QuAFL training driver (runs REAL steps, not a dry-run).

On this container it runs reduced/small variants on the single CPU device;
on a pod, point --mesh-data/--mesh-model at the real topology and the same
program distributes via GSPMD.

Two execution paths:

  * ``--algo spmd`` (default) — the distributed train step
    (``launch/steps.py``): clients live on mesh slots, the quantized
    exchange runs as mesh collectives.
  * ``--algo quafl|fedavg|fedbuff|sequential|quafl_scaffold|adaptive_quafl``
    — the unified algorithm registry (``repro.fed``): the named server
    variant runs through the generic ``simulate()`` harness with the
    standardized metrics schema (``sim_time``, ``bits_up``, ``bits_down``,
    ``h_steps_mean``, ``quant_err``). Any registry algorithm trains any
    architecture — the protocol only sees a params pytree.

Example (the (b) end-to-end driver — ~100M-param model, a few hundred rounds):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --n-slots 4 --log-every 20
Registry path:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --algo quafl --steps 40 --batch 4 --seq 64 --n-slots 4
"""
from __future__ import annotations

import argparse
import time
from functools import partial

import jax
import jax.numpy as jnp
from repro.checkpoint import save_checkpoint
from repro.configs import SHAPES, get_config, get_reduced
from repro.configs.base import FedConfig, ShapeConfig
from repro.data.synthetic import federated_token_task, lm_token_stream
from repro.launch.steps import build_train_step, init_train_state
from repro.models.model import lm_loss


def run_registry(args, cfg, fed, key):
    """Train via the unified algorithm API: registry + simulate()."""
    from repro.fed import make_algorithm, simulate
    from repro.models.model import init_lm

    k_init, k_run = jax.random.split(key)
    params0, _ = init_lm(cfg, k_init)
    loss_fn = partial(lm_loss, cfg)
    pool = max(4, args.local_steps) * args.batch   # per-client token pool
    data, batch_fn = federated_token_task(args.seed, args.n_slots, pool,
                                          args.batch, args.seq,
                                          cfg.vocab_size)

    extra = {"buffer_size": max(2, args.n_slots)} \
        if args.algo == "fedbuff" else {}
    alg = make_algorithm(args.algo, fed, loss_fn=loss_fn, template=params0,
                         batch_fn=batch_fn, **extra)
    eval_toks = lm_token_stream(jax.random.PRNGKey(999), args.batch,
                                args.seq, cfg.vocab_size, client_id=0)

    def eval_fn(params):
        loss, _ = lm_loss(cfg, params, {"tokens": eval_toks})
        return {"server_loss": float(loss)}

    def on_row(row):
        print(f"round {row['round']:5d} server_loss="
              f"{row['server_loss']:.4f} sim_t={row['sim_time']:.0f} "
              f"h_mean={row['h_steps_mean']:.2f} "
              f"qerr={row['quant_err']:.3e} "
              f"bits_up={row['bits_up_total']:.3g} "
              f"bits_down={row['bits_down_total']:.3g}"
              f" ({row['wall_time_s']:.1f}s)", flush=True)

    trace = simulate(alg, params0, data, k_run, rounds=args.steps,
                     eval_every=args.log_every, eval_fn=eval_fn,
                     on_row=on_row)
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, trace.rounds,
                        alg.eval_params(trace.final_state),
                        extra={"arch": cfg.name, "algo": args.algo})
        print(f"checkpoint saved to {args.checkpoint_dir}")
    return trace


def run_spmd(args, cfg, fed, key):
    """Legacy distributed path: mesh-sharded train step."""
    shape = ShapeConfig("cli", args.seq, args.batch * args.n_slots, "train")
    from repro.utils.compat import make_mesh
    mesh = make_mesh((args.mesh_data, args.mesh_model), ("data", "model"))

    with mesh:
        step, _, _ = build_train_step(cfg, fed, mesh, shape,
                                      fed_mode="client_dp", remat=False)
        step = jax.jit(step, donate_argnums=(0,))
        state = init_train_state(cfg, key, args.n_slots)

        def round_batch(rkey):
            toks = []
            for i in range(args.n_slots):
                ks = jax.random.split(jax.random.fold_in(rkey, i),
                                      args.local_steps)
                toks.append(jnp.stack([
                    lm_token_stream(ks[q], args.batch, args.seq,
                                    cfg.vocab_size, client_id=i)
                    for q in range(args.local_steps)]))
            return {"tokens": jnp.stack(toks)}

        eval_toks = lm_token_stream(jax.random.PRNGKey(999), args.batch,
                                    args.seq, cfg.vocab_size, client_id=0)
        t0 = time.time()
        for r in range(args.steps):
            key, kd, kr = jax.random.split(key, 3)
            state, m = step(state, round_batch(kd), jax.random.key_data(kr))
            if (r + 1) % args.log_every == 0 or r == 0:
                loss, _ = lm_loss(cfg, state.server, {"tokens": eval_toks})
                print(f"round {r+1:5d} server_loss={float(loss):.4f} "
                      f"h_mean={float(m['h_steps_mean']):.2f} "
                      f"qerr2={float(m['quant_err_sq']):.3e} "
                      f"({time.time()-t0:.1f}s)", flush=True)
        if args.checkpoint_dir:
            save_checkpoint(args.checkpoint_dir, args.steps, state.server,
                            extra={"arch": cfg.name})
            print(f"checkpoint saved to {args.checkpoint_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="spmd",
                    help="'spmd' (mesh-sharded train step) or any registry "
                         "name: quafl|fedavg|fedbuff|sequential|"
                         "quafl_scaffold|adaptive_quafl")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--quantizer", default="lattice")
    ap.add_argument("--transport", default="dequant_psum")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    fed = FedConfig(n_clients=args.n_slots, s=args.n_slots,
                    local_steps=args.local_steps, lr=args.lr,
                    bits=args.bits, quantizer=args.quantizer,
                    transport=args.transport)
    key = jax.random.PRNGKey(args.seed)
    if args.algo == "spmd":
        run_spmd(args, cfg, fed, key)
    else:
        run_registry(args, cfg, fed, key)


if __name__ == "__main__":
    main()
