"""End-to-end training driver (runs REAL steps, not a dry-run).

On this container it runs reduced/small variants on the single CPU device;
on a pod, point --mesh-data/--mesh-model at the real topology and the same
program distributes via GSPMD.

EVERY algorithm — including the mesh-sharded SPMD path — now runs through
the unified registry (``repro.fed``) and the generic ``simulate()`` harness
with the standardized metrics schema (``sim_time``, ``bits_up``,
``bits_down``, ``h_steps_mean``, ``quant_err``):

  * ``--algo spmd`` (default) — the distributed train step wrapped by
    ``repro.launch.spmd.SpmdAlgorithm``: clients live on mesh slots, the
    quantized exchange runs as mesh collectives, and the rounds land in the
    same Trace format as the simulator algorithms.
  * ``--algo quafl|fedavg|fedbuff|fedbuff_device|sequential|...`` — any
    registry server variant; the protocol only sees a params pytree, so any
    zoo architecture trains under any algorithm.

``--scan-chunk K`` selects the device-resident scanned engine (K-round
``lax.scan`` chunks, one host sync per chunk) for algorithms with the
``device_round`` capability; ``--kernel-backend`` picks the compression
pipeline's kernel implementation (jnp / pallas_interpret / pallas) on both
execution paths. ``--codec-up`` / ``--codec-down`` select the per-direction
compression codec by registry name (``repro.compression.codecs``) for every
algorithm — e.g. ``--codec-up lattice_packed --bits 4`` halves the uplink
wire bytes, ``--codec-up scalar`` runs the FedPAQ-style baseline.

Example (the (b) end-to-end driver — ~100M-param model, a few hundred
rounds; on the spmd path the client count IS the mesh data axis, so grow
--mesh-data on a pod to grow the cohort):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 128 --mesh-data 1 --log-every 20
Registry path, scanned:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --algo quafl --steps 40 --batch 4 --seq 64 --n-slots 4 --scan-chunk 10
"""
from __future__ import annotations

import argparse
from functools import partial

import jax
from repro.checkpoint import save_checkpoint
from repro.configs import get_config, get_reduced
from repro.configs.base import FedConfig
from repro.data.synthetic import federated_token_task, lm_token_stream
from repro.models.model import lm_loss


def run_registry(args, cfg, fed, key):
    """Train via the unified algorithm API: registry + simulate()."""
    from repro.fed import make_algorithm, simulate
    from repro.models.model import init_lm

    k_init, k_run = jax.random.split(key)
    params0, _ = init_lm(cfg, k_init)
    loss_fn = partial(lm_loss, cfg)
    # per-client token pool: every algorithm (spmd included) samples its
    # minibatches with replacement from these rows, so the pool must be
    # large enough that a multi-hundred-round run isn't memorizing a
    # handful of sequences (the pre-refactor spmd loop generated unbounded
    # fresh streams; --pool restores arbitrarily large pools)
    pool = args.pool or max(256, max(4, args.local_steps) * args.batch)
    n_clients = fed.n_clients

    extra = {}
    if args.algo in ("fedbuff", "fedbuff_device"):
        extra = {"buffer_size": max(2, args.n_slots)}
    elif args.algo == "spmd":
        import dataclasses

        from repro.utils.compat import make_mesh
        mesh = make_mesh((args.mesh_data, args.mesh_model),
                         ("data", "model"))
        extra = {"cfg": cfg, "mesh": mesh, "batch": args.batch,
                 "seq": args.seq, "remat": False}
        # spmd maps ONE client per mesh data slice: the client count is
        # --mesh-data, not --n-slots — reconcile fed and the token task
        # loudly rather than training a silently different cohort
        if args.n_slots != args.mesh_data:
            print(f"[train] --algo spmd: client count comes from "
                  f"--mesh-data ({args.mesh_data}), overriding "
                  f"--n-slots {args.n_slots}", flush=True)
        n_clients = args.mesh_data
        fed = dataclasses.replace(fed, n_clients=n_clients, s=n_clients)

    data, batch_fn = federated_token_task(args.seed, n_clients, pool,
                                          args.batch, args.seq,
                                          cfg.vocab_size)
    alg = make_algorithm(args.algo, fed, loss_fn=loss_fn, template=params0,
                         batch_fn=batch_fn, **extra)
    eval_toks = lm_token_stream(jax.random.PRNGKey(999), args.batch,
                                args.seq, cfg.vocab_size, client_id=0)

    def eval_fn(params):
        loss, _ = lm_loss(cfg, params, {"tokens": eval_toks})
        return {"server_loss": float(loss)}

    def on_row(row):
        print(f"round {row['round']:5d} server_loss="
              f"{row.get('server_loss', float('nan')):.4f} "
              f"sim_t={row['sim_time']:.0f} "
              f"h_mean={row['h_steps_mean']:.2f} "
              f"qerr={row['quant_err']:.3e} "
              f"bits_up={row['bits_up_total']:.3g} "
              f"bits_down={row['bits_down_total']:.3g}"
              f" ({row['wall_time_s']:.1f}s)", flush=True)

    trace = simulate(alg, params0, data, k_run, rounds=args.steps,
                     eval_every=args.log_every, eval_fn=eval_fn,
                     on_row=on_row, scan_chunk=args.scan_chunk)
    print(f"engine={trace.engine} us_per_round={trace.us_per_round:.0f}")
    if args.checkpoint_dir:
        save_checkpoint(args.checkpoint_dir, trace.rounds,
                        alg.eval_params(trace.final_state),
                        extra={"arch": cfg.name, "algo": args.algo})
        print(f"checkpoint saved to {args.checkpoint_dir}")
    return trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--algo", default="spmd",
                    help="any registry name: spmd|quafl|fedavg|fedbuff|"
                         "fedbuff_device|sequential|quafl_scaffold|"
                         "adaptive_quafl ('spmd' = mesh-sharded train step "
                         "behind the same protocol)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-slots", type=int, default=2)
    ap.add_argument("--n-clients", type=int, default=0,
                    help="population size n (0 = --n-slots). The per-round "
                         "cohort stays --n-slots; the population engine "
                         "(repro.fed.population) keeps the other n-s "
                         "clients' state as store rows, so large n costs "
                         "memory, not per-round time")
    ap.add_argument("--participation", default="",
                    help="participation spec: uniform|"
                         "gamma_straggler[:strength=a]|"
                         "cyclic:period=P,phase_groups=G "
                         "(empty = FedConfig default, uniform)")
    ap.add_argument("--pool", type=int, default=0,
                    help="token-pool rows per client (0 = auto: at least "
                         "256; all algorithms sample minibatches from "
                         "this finite pool)")
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--quantizer", default="lattice")
    ap.add_argument("--codec-up", default="",
                    help="uplink codec spec (repro.compression.codecs "
                         "registry: lattice|lattice_packed|topk_ef|scalar|"
                         "identity, with name:key=val params, e.g. "
                         "'lattice_packed:bits=4'); empty derives from "
                         "--quantizer/--bits")
    ap.add_argument("--codec-down", default="",
                    help="downlink codec spec (same registry / syntax as "
                         "--codec-up)")
    ap.add_argument("--transport", default="dequant_psum",
                    help="mesh aggregation: dequant_psum|code_allgather|"
                         "shard_local|shard_local_codes|shard_local_rs "
                         "(the shard_local* family runs the shard_map "
                         "exchange with the psum / packed-code all-gather "
                         "/ reduce-scatter transport)")
    ap.add_argument("--kernel-backend", default="jnp",
                    choices=["jnp", "pallas_interpret", "pallas"],
                    help="compression-pipeline kernel implementation, "
                         "threaded through both the registry and spmd paths")
    ap.add_argument("--scan-chunk", default="0",
                    help=">=2 runs device_round-capable algorithms in "
                         "K-round scanned chunks (one host sync per "
                         "chunk); 'auto' picks K from a timed probe "
                         "(RoundEngine.autotune)")
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    args.scan_chunk = (args.scan_chunk if args.scan_chunk == "auto"
                       else int(args.scan_chunk))
    n_clients = args.n_clients or args.n_slots
    if n_clients < args.n_slots:
        raise SystemExit(f"--n-clients {n_clients} < --n-slots "
                         f"{args.n_slots}: cannot sample more clients per "
                         f"round than the population holds")
    fed = FedConfig(n_clients=n_clients, s=args.n_slots,
                    local_steps=args.local_steps, lr=args.lr,
                    bits=args.bits, quantizer=args.quantizer,
                    codec_up=args.codec_up, codec_down=args.codec_down,
                    transport=args.transport,
                    participation=args.participation,
                    kernel_backend=args.kernel_backend)
    key = jax.random.PRNGKey(args.seed)
    run_registry(args, cfg, fed, key)


if __name__ == "__main__":
    main()
