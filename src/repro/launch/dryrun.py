import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, print memory/cost analysis, and dump the roofline terms.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single --out experiments/dryrun

The FIRST TWO LINES of this file set XLA_FLAGS before any jax import so
jax.make_mesh can build the 512-chip production mesh from host devices.
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import FedConfig
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step, fed_mode_for,
                                n_slots_for)


def shape_skip_reason(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.long_500k_ok:
        return cfg.long_500k_note or "long_500k skipped for this arch"
    return ""


def lower_pair(arch: str, shape_name: str, mesh, fed: FedConfig,
               transport: str = "dequant_psum", quantized: bool = True,
               fed_mode: str = None, donate: bool = True,
               moe_impl: str = "", mamba_chunk: int = 0):
    """Lower + compile one (arch × shape × mesh). Returns result dict."""
    cfg = get_config(arch)
    if moe_impl and cfg.moe is not None:
        import dataclasses
        from repro.models.moe import set_moe_mesh
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, impl=moe_impl))
        set_moe_mesh(mesh)
    if mamba_chunk and cfg.mamba is not None:
        import dataclasses
        cfg = cfg.replace(mamba=dataclasses.replace(cfg.mamba,
                                                    chunk=mamba_chunk))
    shape = SHAPES[shape_name]
    if shape.name == "long_500k":
        cfg = cfg.with_long_variant()
    reason = shape_skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "skipped": reason}

    fed_mode = fed_mode or fed_mode_for(arch)
    n_slots = n_slots_for(mesh, fed_mode)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            step, state_spec, (st_sh, b_sh, k_sh) = build_train_step(
                cfg, fed, mesh, shape, fed_mode=fed_mode, transport=transport,
                quantized=quantized)
            batch = input_specs(cfg, shape, n_slots=n_slots,
                                local_steps=fed.local_steps)
            key = jax.ShapeDtypeStruct((2,), jnp.uint32)
            fn = jax.jit(step, in_shardings=(st_sh, b_sh, k_sh),
                         donate_argnums=(0,) if donate else ())
            lowered = fn.lower(state_spec, batch, key)
        elif shape.kind == "prefill":
            step, p_spec, (p_sh, b_sh) = build_prefill_step(cfg, mesh, shape)
            batch = input_specs(cfg, shape)
            fn = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = fn.lower(p_spec, batch)
        else:
            step, p_spec, c_spec, (p_sh, c_sh, t_sh, pos_sh) = \
                build_serve_step(cfg, mesh, shape)
            ins = input_specs(cfg, shape)
            fn = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                         donate_argnums=(1,) if donate else ())
            lowered = fn.lower(p_spec, c_spec, ins["token"], ins["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.hlocost import analyze_hlo
    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_d = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception:
        mem_d = {}
    hlo = compiled.as_text()
    walk = analyze_hlo(hlo)           # trip-count-aware (see hlocost.py)
    coll = walk["collectives"]
    flops = float(walk["flops"])
    bytes_acc = float(walk["bytes"])
    terms = rf.roofline(flops, bytes_acc, coll)
    mf = rf.model_flops(cfg, shape, fed.local_steps, n_slots)
    n_dev = int(np.prod(list(mesh.shape.values())))
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": dict(mesh.shape), "n_devices": n_dev,
        "fed_mode": fed_mode if shape.kind == "train" else "-",
        "transport": transport if shape.kind == "train" else "-",
        "quantized": quantized if shape.kind == "train" else "-",
        "flops_per_device": flops, "bytes_per_device": bytes_acc,
        "xla_cost_analysis": {"flops": float(cost.get("flops", 0.0)),
                              "bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll, "memory": mem_d,
        "roofline": terms,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops if flops else None,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--transport", default="dequant_psum")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--fed-mode", default=None)
    ap.add_argument("--moe-impl", default="")
    ap.add_argument("--bf16-scores", action="store_true")
    ap.add_argument("--mamba-chunk", type=int, default=0)
    ap.add_argument("--bits", type=int, default=8)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    if args.bf16_scores:
        from repro.models import attention as attn_mod
        attn_mod.BF16_SCORE_PARTIALS = True
    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    fed = FedConfig(bits=args.bits, local_steps=args.local_steps)
    archs = ([a for a in list_archs() if a != "paper-mlp"]
             if args.arch == "all" else [args.arch])
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{args.mesh}" + (
                f"__{args.tag}" if args.tag else "")
            path = os.path.join(args.out, tag + ".json")
            try:
                res = lower_pair(arch, shape, mesh, fed,
                                 transport=args.transport,
                                 quantized=not args.no_quant,
                                 fed_mode=args.fed_mode,
                                 moe_impl=args.moe_impl,
                                 mamba_chunk=args.mamba_chunk)
            except Exception as e:
                res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-4000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1, default=str)
            if "error" in res:
                print(f"[FAIL] {tag}: {res['error']}", flush=True)
            elif "skipped" in res:
                print(f"[SKIP] {tag}: {res['skipped']}", flush=True)
            else:
                r = res["roofline"]
                print(f"[OK]   {tag}: flops/dev={res['flops_per_device']:.3e} "
                      f"compute={r['compute_s']:.4f}s mem={r['memory_s']:.4f}s "
                      f"coll={r['collective_s']:.4f}s dom={r['bottleneck']} "
                      f"(compile {res['compile_s']}s)", flush=True)


if __name__ == "__main__":
    main()
