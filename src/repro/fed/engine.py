"""Device-resident round engine: jit/scan-able rounds for every algorithm.

The paper's headline claim is *simultaneous* support for data heterogeneity,
partial asynchrony, and compression at up-to-300-node scale — which requires
the per-round host overhead (python loop, per-round dispatch, metric syncs)
to vanish from the hot path. This module is the single home for that
machinery:

  * **``device_round`` capability** — an algorithm that exposes
    ``device_round(state, data, key) -> (state, metrics)`` as PURE traced
    code (state a pytree, metrics a dict of device scalars with a fixed
    structure, no ``float()``/``int()``/host control flow) can be run in
    K-round ``lax.scan`` chunks with a single host sync per chunk.
    :class:`DeviceFedAlgorithm` is the structural type;
    :func:`supports_scan` is the capability check. Algorithms whose control
    NEEDS the host (e.g. the adaptive bit-width walk, which selects a jit
    cache by python-int bits) can instead provide
    ``scan_rounds(state, data, key, length)`` and manage their own chunking.

  * **:class:`RoundEngine`** — compiles and caches one scanned chunk
    program per chunk length. The scan body splits the key exactly like the
    eager ``simulate()`` loop (``key, sub = split(key)`` per round), so a
    scanned run reproduces the eager run under the same seed — bit-for-bit
    in the equivalence suite, up to float32 rounding for kernels XLA fuses
    differently inside a multi-round loop body.

  * **:class:`RingBuffer`** — a fixed-capacity, device-resident event queue
    (times + client ids, empty slots at ``+inf``) replacing the python
    min-heap ``repro.fed.clock.ArrivalQueue``. ``ring_pop`` is a masked-min
    with the heap's lexicographic ``(time, client)`` tie-break, so the pop
    order is pinned bit-for-bit against the heap over any event stream
    (property test in ``tests/test_engine.py``).

  * **seed bridge** — :func:`fedbuff_completion_table` replays the legacy
    numpy event stream host-side into a ``(client, occurrence) -> duration``
    table, so the device-resident FedBuff can consume the EXACT draws of the
    python implementation and be pinned bit-for-bit against it.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.fed.api import FedAlgorithm


@runtime_checkable
class DeviceFedAlgorithm(FedAlgorithm, Protocol):
    """A :class:`FedAlgorithm` whose round is pure traced code.

    ``device_round`` must be side-effect free and jit/scan-able: the state a
    registered pytree, every metric a device scalar, the metrics dict
    structure identical every round. ``round`` may simply alias (a jitted)
    ``device_round``.
    """

    def device_round(self, state, data, key) -> Tuple[Any, Dict[str, Any]]:
        ...


def supports_scan(alg) -> bool:
    """True if ``alg`` can run scanned chunks — either via the generic
    ``device_round`` capability or its own ``scan_rounds`` implementation."""
    return (callable(getattr(alg, "device_round", None))
            or callable(getattr(alg, "scan_rounds", None)))


# ---------------------------------------------------------------------------
# fixed-capacity device event queue (replaces clock.ArrivalQueue's heap)
# ---------------------------------------------------------------------------

class RingBuffer(NamedTuple):
    """Fixed-capacity (time, client) event set. Empty slots hold
    ``times=+inf`` / ``clients=-1`` so the masked-min pop skips them."""
    times: jnp.ndarray    # (cap,) float32
    clients: jnp.ndarray  # (cap,) int32

    @property
    def capacity(self) -> int:
        return self.times.shape[0]


def ring_init(capacity: int) -> RingBuffer:
    return RingBuffer(times=jnp.full((capacity,), jnp.inf, jnp.float32),
                      clients=jnp.full((capacity,), -1, jnp.int32))


def ring_size(rb: RingBuffer) -> jnp.ndarray:
    return jnp.sum(jnp.isfinite(rb.times).astype(jnp.int32))


def ring_push(rb: RingBuffer, t, client) -> RingBuffer:
    """Insert into the first empty slot. The caller must not push into a
    full buffer (the FedBuff formulation holds exactly one pending event per
    client, so capacity = n_clients is never exceeded)."""
    slot = jnp.argmax(~jnp.isfinite(rb.times))
    return RingBuffer(
        times=rb.times.at[slot].set(jnp.asarray(t, jnp.float32)),
        clients=rb.clients.at[slot].set(jnp.asarray(client, jnp.int32)))


def ring_peek(rb: RingBuffer) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(time, client) of the next event — the heap's lexicographic min:
    smallest time, ties broken by smallest client id (then first slot)."""
    t_min = jnp.min(rb.times)
    big = jnp.iinfo(jnp.int32).max
    cand = jnp.where(rb.times == t_min, rb.clients, big)
    c_min = jnp.min(cand)
    return t_min, c_min


def ring_pop(rb: RingBuffer) -> Tuple[RingBuffer, jnp.ndarray, jnp.ndarray]:
    """Remove and return the lexicographic-min event. Masked-min formulation
    of ``heapq.heappop`` on ``(time, client)`` tuples — pinned bit-for-bit
    against :class:`repro.fed.clock.ArrivalQueue` in the tests."""
    t_min, c_min = ring_peek(rb)
    slot = jnp.argmax((rb.times == t_min) & (rb.clients == c_min))
    out = RingBuffer(times=rb.times.at[slot].set(jnp.inf),
                     clients=rb.clients.at[slot].set(-1))
    return out, t_min, c_min


# ---------------------------------------------------------------------------
# seed bridge: legacy numpy event stream -> device-consumable draw table
# ---------------------------------------------------------------------------

def fedbuff_completion_table(key, lam, local_steps: int,
                             n_events: int) -> np.ndarray:
    """Replay the legacy ``(np.random.Generator, ArrivalQueue)`` event
    stream host-side and return ``table[i, k]`` = the duration drawn for
    client ``i``'s ``k``-th completion (float32, ``(n, n_events + 1)``).

    The replay consumes the numpy rng in EXACTLY the legacy order — n
    initial draws (clients 0..n-1), then one redraw per pop, in pop order —
    so a device-resident FedBuff indexing ``table[i, occ_i]`` sees the same
    durations as the python implementation seeded from the same ``key``
    (the rng seed derivation matches ``FedBuff._seed``).
    """
    from repro.fed.clock import ArrivalQueue, completion_time
    rng = np.random.default_rng(
        int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))
    n = len(lam)
    table = np.zeros((n, n_events + 1), np.float32)
    occ = np.zeros(n, np.int64)
    q = ArrivalQueue()
    for i in range(n):
        d = completion_time(rng, local_steps, lam[i])
        table[i, 0] = d
        occ[i] = 1
        q.push(d, i)
    for _ in range(n_events):
        t_now, i = q.pop()
        d = completion_time(rng, local_steps, lam[i])
        if occ[i] >= table.shape[1]:   # one client absorbed every event
            table = np.pad(table, ((0, 0), (0, n_events)))
        table[i, occ[i]] = d
        occ[i] += 1
        q.push(t_now + d, i)
    return table


# ---------------------------------------------------------------------------
# the engine: cached scanned-chunk programs
# ---------------------------------------------------------------------------

# chunk lengths the autotuner probes (each costs one compile + two runs)
AUTOTUNE_CANDIDATES = (4, 16, 64)


class RoundEngine:
    """Runs an algorithm's rounds as jitted ``lax.scan`` chunks.

    One compiled program per distinct chunk length (cached); the stacked
    per-round metrics come back as ONE device value, so a chunk costs a
    single host sync instead of one per round. The key-split schedule inside
    the scan body is identical to the eager ``simulate()`` loop, making
    scanned runs bit-for-bit reproductions of eager runs.

    :meth:`autotune` picks the chunk length empirically —
    ``simulate(..., scan_chunk="auto")`` exposes it.
    """

    def __init__(self, alg):
        if not supports_scan(alg):
            raise TypeError(
                f"{type(alg).__name__} exposes neither device_round nor "
                "scan_rounds; run it through the eager simulate() path")
        self.alg = alg
        self._chunk_fns: Dict[int, Any] = {}
        self.tuned_chunk: int | None = None

    def autotune(self, params0, data, key, cap: int = 0,
                 candidates=AUTOTUNE_CANDIDATES) -> int:
        """Pick a chunk length from measured us_per_round of 2-chunk probes.

        Each candidate length runs TWO chunks on a disposable
        ``alg.init(params0)`` state: the first pays the compile + warmup,
        the second is timed. The probe state is donated through the chain,
        so peak memory stays one state generation; the probe ``key`` should
        be derived OUT of the caller's key schedule (``simulate`` folds one
        off) so tuning never perturbs the run's round keys. ``cap > 0``
        bounds the candidates (e.g. to ``eval_every`` so chunks don't
        straddle eval points). The winner is cached on the engine — compiled
        chunk programs for the winning length are reused by the real run.
        """
        if self.tuned_chunk is not None:
            return self.tuned_chunk
        import time
        cands = sorted({min(c, cap) if cap else c
                        for c in candidates if c >= 2})
        if not cands:
            cands = [2]
        best, best_us = cands[0], float("inf")
        state = self.alg.init(params0)
        for c in cands:
            key, state, ms = self.run_chunk(state, data, key, c)  # warmup
            jax.block_until_ready(ms)
            t0 = time.perf_counter()
            key, state, ms = self.run_chunk(state, data, key, c)
            jax.block_until_ready(ms)
            us = (time.perf_counter() - t0) / c * 1e6
            if us < best_us:
                best, best_us = c, us
        self.tuned_chunk = best
        return best

    def _chunk_program(self, length: int):
        """The python chunk body for ``length`` rounds — the ONE closure
        shared by :meth:`run_chunk` (jitted + donated), :meth:`traced_chunk`
        (jaxpr for the analyzers), and :meth:`lowered_chunk` (compiled
        executable for the donation audit)."""
        round_fn = self.alg.device_round

        def run(state, data, key):
            def body(carry, _):
                k, st = carry
                k, sub = jax.random.split(k)
                st, m = round_fn(st, data, sub)
                return (k, st), m

            (k, st), ms = jax.lax.scan(body, (key, state), None,
                                       length=length)
            return k, st, ms

        return run

    def _commit_carry(self, state, key):
        """Normalize the carry of a MESH-sharded state and return
        ``(state, key, out_shardings)``; single-device states pass through
        with ``out_shardings=None``.

        Two-part contract for mesh states (spmd): every unplaced leaf (host
        scalars from ``init``, the caller's host-made key) is placed
        replicated on the state's mesh, and the chunk outputs are pinned to
        the input shardings. Without both, the carry is not a fixed point:
        GSPMD repicks output layouts freely (a physical reshard of every
        leaf per chunk on a real mesh) and the first call's
        uncommitted-leaf signature differs from every later one — a silent
        recompile per run, which the recompile sentinel flags.

        Single-device states are left alone: their carry signature is
        already stable, and pinning ``out_shardings`` there would itself
        split the jit cache on the first call's uncommitted inputs."""
        from jax.sharding import NamedSharding, PartitionSpec
        mesh = None
        for leaf in jax.tree_util.tree_leaves(state):
            sh = getattr(leaf, "sharding", None)
            if isinstance(sh, NamedSharding):
                mesh = sh.mesh
                break
        if mesh is None:
            return state, key, None
        repl = NamedSharding(mesh, PartitionSpec())

        def place(leaf):
            if (hasattr(leaf, "sharding")
                    and not isinstance(leaf.sharding, NamedSharding)):
                return jax.device_put(leaf, repl)
            return leaf

        state = jax.tree_util.tree_map(place, state)
        key = jax.device_put(key, repl)
        state_sh = jax.tree_util.tree_map(lambda l: l.sharding, state)
        return state, key, (key.sharding, state_sh, None)

    def chunk_fn(self, length: int, carry_out=None):
        """The cached jitted chunk program for ``length`` (compiling it on
        first use, with the carry outputs pinned to ``carry_out`` when
        given). Exposed so the recompile sentinel can interrogate the jit
        cache (``fn._cache_size()``) after a run."""
        fn = self._chunk_fns.get(length)
        if fn is None:
            kw = {}
            if carry_out is not None:
                kw["out_shardings"] = carry_out
            fn = jax.jit(self._chunk_program(length), donate_argnums=(0,),
                         **kw)
            self._chunk_fns[length] = fn
        return fn

    def run_chunk(self, state, data, key, length: int):
        """Advance ``length`` rounds on device.

        Returns ``(key, state, stacked_metrics)`` where ``stacked_metrics``
        leaves carry a leading ``(length,)`` axis (round-major).

        The INPUT ``state`` buffers are DONATED to the compiled chunk: for
        d=2^20+ regimes the scan carry reuses the caller's state
        allocation instead of holding both generations live across the
        chunk entry (ROADMAP scan-polish item a). Callers must treat the
        passed-in state as consumed — both ``simulate()`` and the adaptive
        walk already discard it in favour of the returned state. The
        (tiny, caller-supplied) ``key`` is NOT donated.
        """
        custom = getattr(self.alg, "scan_rounds", None)
        if custom is not None:
            return custom(state, data, key, length)
        state, key, carry_out = self._commit_carry(state, key)
        return self.chunk_fn(length, carry_out)(state, data, key)

    # -- analyzer hooks (repro.analysis) ------------------------------------

    def traced_round(self, state, data, key):
        """Closed jaxpr of ONE round — ``device_round`` exactly as the scan
        body calls it. Tracing is abstract: no device work, no state
        consumed."""
        return jax.make_jaxpr(
            lambda st, d, k: self.alg.device_round(st, d, k)
        )(state, data, key)

    def traced_chunk(self, state, data, key, length: int):
        """Closed jaxpr of the ``length``-round scanned chunk program (the
        same closure :meth:`run_chunk` jits, including the per-round
        ``key, sub = split(key)`` schedule)."""
        return jax.make_jaxpr(self._chunk_program(length))(state, data, key)

    def wire_provenance(self, state, data, key):
        """Message/collective provenance of one traced round, for the
        wire-truth audit: ``(closed, marks, collectives)`` where marks are
        the ``wire_mark`` sites (params, aval, path) and collectives the
        ``(prim, [(aval, taint), ...], path)`` facts from the taint flow.
        Analysis imports stay lazy — tracing never pays for them unless a
        caller asks for provenance."""
        from repro.analysis.wire import collect_wire_facts
        closed = self.traced_round(state, data, key)
        marks, colls = collect_wire_facts(closed)
        return closed, marks, colls

    def lowered_chunk(self, state, data, key, length: int):
        """The chunk program lowered with the donation contract of
        :meth:`run_chunk` (``donate_argnums=(0,)``) — ``.compile()`` it to
        audit the executable's input-output aliasing. Deliberately NOT the
        cached run fn: auditing must not warm (or be confused by) the run
        cache."""
        return jax.jit(self._chunk_program(length),
                       donate_argnums=(0,)).lower(state, data, key)
