"""Generic simulation harness: run ANY registered algorithm to a budget.

The paper's comparisons (§5, App. A) hold the *budget* fixed — equal
simulated wall-clock, or equal communication bits — and let each algorithm
spend it its own way (QuAFL polls often and cheaply; FedAvg waits for
stragglers; FedBuff flushes a buffer). :func:`simulate` runs one
:class:`repro.fed.FedAlgorithm` until its budget is exhausted and emits ONE
trace format; :func:`compare` does it for a named set of algorithms under
identical seeds and budgets, which is the apples-to-apples harness every
figure-style experiment (and ``benchmarks/bench_algorithms.py``) drives.

A trace row is a plain dict with the standardized metrics schema keys
(:data:`repro.fed.api.METRIC_KEYS`, all PER-ROUND exactly as the algorithm
returned them) plus ``round``, ``wall_time_s`` (host wall-clock when the
row was recorded), the CUMULATIVE counters ``bits_up_total`` /
``bits_down_total``, and whatever the optional ``eval_fn`` returns (dict
results are merged in; scalars land under ``"eval"``).

**Execution engines.** Two paths produce that trace:

  * **eager** (default) — one python-loop iteration per round. Any
    algorithm runs here, including host-control ones (python FedBuff's
    event heap, the adaptive bit-width walk).
  * **scanned** (``scan_chunk=K``) — for algorithms with the
    ``device_round`` capability (:mod:`repro.fed.engine`), rounds run in
    jitted ``lax.scan`` chunks of up to K rounds with ONE host sync per
    chunk; the chunk entry DONATES the carried state buffers (the previous
    chunk's output is consumed, not copied — rows and evals are recorded
    from the returned state before the next chunk reuses it). The key-split schedule matches the eager loop, so a scanned run
    is bit-for-bit the eager run under the same seed (exact in the
    equivalence tests for uncompressed/qsgd rounds; the rotation-fused
    lattice kernels agree to float32 rounding at chunk lengths >= 2, where
    XLA fuses the loop body differently than the standalone round);
    per-round row semantics are preserved (``record_every=1`` still yields
    one exact row per round, rebuilt from the chunk's stacked metrics).
    Differences:
    ``until_sim_time`` / ``until_bits`` budgets are only CHECKED at chunk
    boundaries (the run may overshoot by up to one chunk), chunks shrink to
    land ``eval_fn`` rounds on chunk boundaries, and ``wall_time_s`` is the
    chunk's recording time for every row in the chunk. Algorithms without
    the capability silently fall back to the eager path.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Union

import jax
import numpy as np

from repro.fed.api import FedAlgorithm, normalize_metrics
from repro.fed.engine import RoundEngine, supports_scan


@dataclass
class Trace:
    """The single trace format every simulation emits."""
    algorithm: str
    rows: List[Dict[str, Any]] = field(default_factory=list)
    final_state: Any = None
    rounds: int = 0
    wall_time_s: float = 0.0
    eval_time_s: float = 0.0   # host time spent inside eval_fn
    engine: str = "eager"      # 'eager' | 'scanned'
    scan_chunk: int = 0        # resolved chunk length (scanned engine only)

    @property
    def us_per_round(self) -> float:
        """Mean wall time per algorithm round, EXCLUDING eval_fn time — so
        benchmark numbers measure round cost, not eval cadence."""
        return ((self.wall_time_s - self.eval_time_s)
                / max(self.rounds, 1) * 1e6)

    @property
    def final(self) -> Dict[str, Any]:
        return self.rows[-1] if self.rows else {}

    def column(self, key: str) -> List[Any]:
        return [r.get(key) for r in self.rows]


class _Recorder:
    """Row construction + eval bookkeeping shared by both engines, so the
    scanned path emits EXACTLY the eager path's rows."""

    def __init__(self, trace: Trace, alg, eval_fn, on_row, t0: float):
        self.trace, self.alg = trace, alg
        self.eval_fn, self.on_row, self.t0 = eval_fn, on_row, t0
        self.state = None          # kept current by the driving loop
        self.evaled_round = 0      # last round whose row carried an eval

    def run_eval(self, r: int):
        t_e = time.time()
        res = self.eval_fn(self.alg.eval_params(self.state))
        self.trace.eval_time_s += time.time() - t_e
        self.evaled_round = r
        return res if isinstance(res, dict) else {"eval": res}

    def record(self, r: int, metrics, bits_up, bits_down, do_eval: bool):
        row = dict(normalize_metrics(metrics), round=r,
                   bits_up_total=float(bits_up),
                   bits_down_total=float(bits_down),
                   wall_time_s=time.time() - self.t0)
        if do_eval and self.eval_fn is not None:
            row.update(self.run_eval(r))
        self.trace.rows.append(row)
        if self.on_row is not None:
            self.on_row(row)

    def finalize(self, r: int, metrics, bits_up, bits_down):
        """Backstop exit (unreachable budget / max_rounds): guarantee the
        final round has a (fully evaluated) row. If an eval-less row for
        the final round was already recorded (and streamed), update it in
        place so on_row never fires twice for one round."""
        rows = self.trace.rows
        if r and (not rows or rows[-1]["round"] != r):
            self.record(r, metrics, bits_up, bits_down, True)
        elif r and self.eval_fn is not None and self.evaled_round != r:
            rows[-1].update(self.run_eval(r))


def simulate(alg: FedAlgorithm, params0, data, key, *,
             rounds: Optional[int] = None,
             until_sim_time: Optional[float] = None,
             until_bits: Optional[float] = None,
             eval_every: int = 10,
             record_every: int = 0,
             eval_fn: Optional[Callable[[Any], Any]] = None,
             on_row: Optional[Callable[[Dict[str, Any]], None]] = None,
             name: str = "", max_rounds: int = 100_000,
             scan_chunk: Union[int, str] = 0) -> Trace:
    """Run ``alg`` from ``params0`` until the budget is exhausted.

    Budgets compose (first one hit wins): ``rounds`` server rounds,
    ``until_sim_time`` simulated seconds, ``until_bits`` total communication
    bits (up + down). At least one must be given; ``max_rounds`` is the
    backstop when a sim-time/bits budget is unreachable (e.g. an algorithm
    that never sends bits), and the final round is always recorded (and
    evaluated) even when the loop ends on the backstop. ``eval_fn(params)``
    is called every ``eval_every`` rounds (and on the final round); its
    result lands in the trace row. ``record_every`` records metrics-only
    rows on its own (usually denser) cadence WITHOUT paying for an eval —
    e.g. ``record_every=1, eval_every=0`` traces every round's
    ``h_zero_frac`` but evaluates only once, at the end. ``on_row`` streams
    every recorded row to the caller as it happens (progress logging).

    ``scan_chunk=K`` (K >= 2) selects the scanned engine for algorithms
    with the ``device_round`` capability: rounds execute in jitted
    ``lax.scan`` chunks of up to K rounds, one host sync per chunk (see the
    module docstring for the exact semantics). Prefer K dividing
    ``eval_every`` — each distinct chunk length compiles once.
    ``scan_chunk="auto"`` autotunes K before the run
    (:meth:`repro.fed.engine.RoundEngine.autotune`): each candidate length
    runs a compile+warmup chunk and one timed chunk on a disposable probe
    state, with a probe key folded OUT of the run's key schedule — the
    tuned run's round keys (and trace) are identical to passing the winning
    K explicitly. The resolved length lands in ``Trace.scan_chunk``.

    Eager path: device->host syncs happen only where a value is genuinely
    needed on the host — the stop condition of an active sim-time/bits
    budget, and row recording. A rounds-only budget leaves the device
    pipeline free to run ahead between recorded rows.
    """
    if rounds is None and until_sim_time is None and until_bits is None:
        raise ValueError("give at least one budget: rounds / until_sim_time "
                         "/ until_bits")
    if scan_chunk == "auto" and not supports_scan(alg):
        scan_chunk = 0       # autotune has nothing to tune: eager fallback
    if scan_chunk and (scan_chunk == "auto" or scan_chunk > 1) \
            and supports_scan(alg):
        return _simulate_scanned(
            alg, params0, data, key, rounds=rounds,
            until_sim_time=until_sim_time, until_bits=until_bits,
            eval_every=eval_every, record_every=record_every,
            eval_fn=eval_fn, on_row=on_row, name=name,
            max_rounds=max_rounds, scan_chunk=scan_chunk)
    trace = Trace(algorithm=name or type(alg).__name__)
    state = alg.init(params0)
    # cumulative counters accumulate device-side (no per-round sync)
    bits_up = bits_down = 0.0
    t0 = time.time()
    rec = _Recorder(trace, alg, eval_fn, on_row, t0)
    r = 0
    metrics = {}
    limit = min(rounds, max_rounds) if rounds is not None else max_rounds

    done = False
    while r < limit and not done:
        key, sub = jax.random.split(key)
        state, metrics = alg.round(state, data, sub)
        rec.state = state
        r += 1
        bits_up = bits_up + metrics.get("bits_up", 0.0)
        bits_down = bits_down + metrics.get("bits_down", 0.0)
        done = rounds is not None and r >= rounds
        if not done and until_sim_time is not None:
            done = float(metrics.get("sim_time", 0.0)) >= until_sim_time
        if not done and until_bits is not None:
            done = float(bits_up) + float(bits_down) >= until_bits
        do_eval = done or (eval_every and r % eval_every == 0)
        if do_eval or (record_every and r % record_every == 0):
            rec.record(r, metrics, bits_up, bits_down, do_eval)
    rec.state = state
    rec.finalize(r, metrics, bits_up, bits_down)
    trace.final_state = state
    trace.rounds = r
    trace.wall_time_s = time.time() - t0
    return trace


def _simulate_scanned(alg, params0, data, key, *, rounds, until_sim_time,
                      until_bits, eval_every, record_every, eval_fn, on_row,
                      name, max_rounds, scan_chunk) -> Trace:
    """The scanned engine: K-round jitted chunks, one host sync per chunk.

    Bit accumulation mirrors the eager path's on-device float32 adds
    (``np.float32`` partial sums), so ``bits_*_total`` rows match the eager
    engine exactly for device algorithms.
    """
    trace = Trace(algorithm=name or type(alg).__name__, engine="scanned")
    # the engine's compiled chunk programs are cached ON the algorithm (like
    # the eager path's jitted round), so repeated simulate() calls — warmup
    # then timed bench runs, compare() sweeps — never recompile
    engine = getattr(alg, "_round_engine", None)
    if engine is None or engine.alg is not alg:
        engine = RoundEngine(alg)
        try:
            alg._round_engine = engine
        except AttributeError:   # slotted/frozen algorithm: uncached
            pass
    limit = min(rounds, max_rounds) if rounds is not None else max_rounds
    if scan_chunk == "auto":
        # probe BEFORE the run state exists (one state generation live) and
        # with a key folded off the run's stream — the tuned run's round
        # keys are identical to passing the winning K explicitly
        cap = limit
        if eval_fn is not None and eval_every:
            cap = min(cap, eval_every)
        scan_chunk = engine.autotune(params0, data,
                                     jax.random.fold_in(key, 0x5EED),
                                     cap=cap)
    trace.scan_chunk = int(scan_chunk)
    state = alg.init(params0)
    bits_up = np.float32(0.0)
    bits_down = np.float32(0.0)
    t0 = time.time()
    rec = _Recorder(trace, alg, eval_fn, on_row, t0)
    r = 0
    metrics = {}

    done = False
    while r < limit and not done:
        n = limit - r
        if eval_fn is not None and eval_every:
            # shrink so eval rounds land on chunk boundaries, where the
            # state (hence eval_params) is materialized
            n = min(n, eval_every - (r % eval_every))
        n = min(n, scan_chunk)
        key, state, stacked = engine.run_chunk(state, data, key, n)
        rec.state = state
        host = jax.device_get(stacked)   # the chunk's single host sync
        for j in range(n):
            rj = r + j + 1
            mj = {k: v[j] for k, v in host.items()}
            bits_up = np.float32(bits_up + mj.get("bits_up", 0.0))
            bits_down = np.float32(bits_down + mj.get("bits_down", 0.0))
            done_j = rounds is not None and rj >= rounds
            at_boundary = j == n - 1
            # sim-time / bits budgets: checked at chunk boundaries only
            if not done_j and at_boundary and until_sim_time is not None:
                done_j = float(mj.get("sim_time", 0.0)) >= until_sim_time
            if not done_j and at_boundary and until_bits is not None:
                done_j = float(bits_up) + float(bits_down) >= until_bits
            do_eval = done_j or (eval_every and rj % eval_every == 0)
            if do_eval or (record_every and rj % record_every == 0):
                # eval only ever fires at a boundary (chunks are aligned)
                rec.record(rj, mj, bits_up, bits_down,
                           do_eval and at_boundary)
            done = done or done_j
            metrics = mj
        r += n
    rec.state = state
    rec.finalize(r, metrics, bits_up, bits_down)
    trace.final_state = state
    trace.rounds = r
    trace.wall_time_s = time.time() - t0
    return trace


def compare(algorithms: Dict[str, FedAlgorithm], params0, data, key,
            **sim_kw) -> Dict[str, Trace]:
    """Run every named algorithm from the SAME initial params, key stream,
    and budget — the paper's equal-clock / equal-bits comparison. Returns
    ``{name: Trace}`` in input order."""
    return {name: simulate(alg, params0, data, key, name=name, **sim_kw)
            for name, alg in algorithms.items()}
