"""Generic simulation harness: run ANY registered algorithm to a budget.

The paper's comparisons (§5, App. A) hold the *budget* fixed — equal
simulated wall-clock, or equal communication bits — and let each algorithm
spend it its own way (QuAFL polls often and cheaply; FedAvg waits for
stragglers; FedBuff flushes a buffer). :func:`simulate` runs one
:class:`repro.fed.FedAlgorithm` until its budget is exhausted and emits ONE
trace format; :func:`compare` does it for a named set of algorithms under
identical seeds and budgets, which is the apples-to-apples harness every
figure-style experiment (and ``benchmarks/bench_algorithms.py``) drives.

A trace row is a plain dict with the standardized metrics schema keys
(:data:`repro.fed.api.METRIC_KEYS`, all PER-ROUND exactly as the algorithm
returned them) plus ``round``, ``wall_time_s`` (host wall-clock when the
row was recorded), the CUMULATIVE counters ``bits_up_total`` /
``bits_down_total``, and whatever the optional ``eval_fn`` returns (dict
results are merged in; scalars land under ``"eval"``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.fed.api import FedAlgorithm, normalize_metrics


@dataclass
class Trace:
    """The single trace format every simulation emits."""
    algorithm: str
    rows: List[Dict[str, Any]] = field(default_factory=list)
    final_state: Any = None
    rounds: int = 0
    wall_time_s: float = 0.0
    eval_time_s: float = 0.0   # host time spent inside eval_fn

    @property
    def us_per_round(self) -> float:
        """Mean wall time per algorithm round, EXCLUDING eval_fn time — so
        benchmark numbers measure round cost, not eval cadence."""
        return ((self.wall_time_s - self.eval_time_s)
                / max(self.rounds, 1) * 1e6)

    @property
    def final(self) -> Dict[str, Any]:
        return self.rows[-1] if self.rows else {}

    def column(self, key: str) -> List[Any]:
        return [r.get(key) for r in self.rows]


def simulate(alg: FedAlgorithm, params0, data, key, *,
             rounds: Optional[int] = None,
             until_sim_time: Optional[float] = None,
             until_bits: Optional[float] = None,
             eval_every: int = 10,
             record_every: int = 0,
             eval_fn: Optional[Callable[[Any], Any]] = None,
             on_row: Optional[Callable[[Dict[str, Any]], None]] = None,
             name: str = "", max_rounds: int = 100_000) -> Trace:
    """Run ``alg`` from ``params0`` until the budget is exhausted.

    Budgets compose (first one hit wins): ``rounds`` server rounds,
    ``until_sim_time`` simulated seconds, ``until_bits`` total communication
    bits (up + down). At least one must be given; ``max_rounds`` is the
    backstop when a sim-time/bits budget is unreachable (e.g. an algorithm
    that never sends bits), and the final round is always recorded (and
    evaluated) even when the loop ends on the backstop. ``eval_fn(params)``
    is called every ``eval_every`` rounds (and on the final round); its
    result lands in the trace row. ``record_every`` records metrics-only
    rows on its own (usually denser) cadence WITHOUT paying for an eval —
    e.g. ``record_every=1, eval_every=0`` traces every round's
    ``h_zero_frac`` but evaluates only once, at the end. ``on_row`` streams
    every recorded row to the caller as it happens (progress logging).

    Device->host syncs happen only where a value is genuinely needed on the
    host: the stop condition of an active sim-time/bits budget, and row
    recording. A rounds-only budget leaves the device pipeline free to run
    ahead between recorded rows.
    """
    if rounds is None and until_sim_time is None and until_bits is None:
        raise ValueError("give at least one budget: rounds / until_sim_time "
                         "/ until_bits")
    trace = Trace(algorithm=name or type(alg).__name__)
    state = alg.init(params0)
    # cumulative counters accumulate device-side (no per-round sync)
    bits_up = bits_down = 0.0
    t0 = time.time()
    r = 0
    metrics = {}
    limit = min(rounds, max_rounds) if rounds is not None else max_rounds

    evaled_round = 0   # last round whose row carried an eval_fn result

    def run_eval():
        nonlocal evaled_round
        t_e = time.time()
        res = eval_fn(alg.eval_params(state))
        trace.eval_time_s += time.time() - t_e
        evaled_round = r
        return res if isinstance(res, dict) else {"eval": res}

    def record(do_eval: bool):
        row = dict(normalize_metrics(metrics), round=r,
                   bits_up_total=float(bits_up),
                   bits_down_total=float(bits_down),
                   wall_time_s=time.time() - t0)
        if do_eval and eval_fn is not None:
            row.update(run_eval())
        trace.rows.append(row)
        if on_row is not None:
            on_row(row)

    done = False
    while r < limit and not done:
        key, sub = jax.random.split(key)
        state, metrics = alg.round(state, data, sub)
        r += 1
        bits_up = bits_up + metrics.get("bits_up", 0.0)
        bits_down = bits_down + metrics.get("bits_down", 0.0)
        done = rounds is not None and r >= rounds
        if not done and until_sim_time is not None:
            done = float(metrics.get("sim_time", 0.0)) >= until_sim_time
        if not done and until_bits is not None:
            done = float(bits_up) + float(bits_down) >= until_bits
        do_eval = done or (eval_every and r % eval_every == 0)
        if do_eval or (record_every and r % record_every == 0):
            record(do_eval)
    # backstop exit (unreachable budget / max_rounds): the loop above only
    # guarantees a final evaluated row when `done` fired — make sure
    # trace.final and the final eval always exist. If an eval-less row for
    # the final round was already recorded (and streamed), update it in
    # place rather than re-recording, so on_row never fires twice for one
    # round.
    if r and (not trace.rows or trace.rows[-1]["round"] != r):
        record(True)
    elif r and eval_fn is not None and evaled_round != r:
        trace.rows[-1].update(run_eval())
    trace.final_state = state
    trace.rounds = r
    trace.wall_time_s = time.time() - t0
    return trace


def compare(algorithms: Dict[str, FedAlgorithm], params0, data, key,
            **sim_kw) -> Dict[str, Trace]:
    """Run every named algorithm from the SAME initial params, key stream,
    and budget — the paper's equal-clock / equal-bits comparison. Returns
    ``{name: Trace}`` in input order."""
    return {name: simulate(alg, params0, data, key, name=name, **sim_kw)
            for name, alg in algorithms.items()}
