"""Unified federated-algorithm API.

One protocol (:class:`FedAlgorithm`: ``init / round / eval_params``), one
metrics schema (:data:`METRIC_KEYS`), one clock (:mod:`repro.fed.clock`),
one registry (:func:`make_algorithm`), one population store + participation
spec family (:mod:`repro.fed.population`), and one simulation harness
(:func:`simulate` / :func:`compare`) for every server variant in the repo —
the paper's apples-to-apples comparison (§5, App. A) as infrastructure.

    from repro.fed import make_algorithm, compare
    algs = {n: make_algorithm(n, fed, loss_fn=..., template=...,
                              batch_fn=...)
            for n in ("quafl", "fedavg")}
    traces = compare(algs, params0, data, key, until_sim_time=1000.0,
                     eval_fn=lambda p: {"loss": float(loss(p, test)[0])})
"""
from repro.fed.api import (FedAlgorithm, METRIC_KEYS,  # noqa: F401
                           normalize_metrics)
from repro.fed.clock import (ArrivalQueue, client_speeds,  # noqa: F401
                             completion_time, completion_time_device,
                             expected_steps, lazy_h_steps, sample_clients,
                             speeds_for, straggler_round_time)
from repro.fed.engine import (DeviceFedAlgorithm, RingBuffer,  # noqa: F401
                              RoundEngine, fedbuff_completion_table,
                              ring_init, ring_peek, ring_pop, ring_push,
                              ring_size, supports_scan)
from repro.fed.population import (CyclicParticipation,  # noqa: F401
                                  GammaStragglerParticipation, Participation,
                                  Population, UniformParticipation,
                                  build_population, client_keys, client_mesh,
                                  floyd_sample, gather_rows,
                                  lazy_h_steps_per_client,
                                  register_participation,
                                  registered_participations,
                                  resolve_participation, scatter_rows,
                                  shard_population, uniform_sample, with_rows)
from repro.fed.registry import (make_algorithm,  # noqa: F401
                                register_algorithm, registered_algorithms)
from repro.fed.simulate import Trace, compare, simulate  # noqa: F401
