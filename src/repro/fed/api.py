"""The federated-algorithm protocol and the standardized metrics schema.

The paper's contribution is a *family* of server algorithms compared under
one clock — QuAFL vs. FedAvg vs. FedBuff at equal simulated wall-clock and
equal communication bits (§5, App. A). Every server variant in this repo
therefore implements ONE protocol so a single harness
(:mod:`repro.fed.simulate`) can run any of them to an equal budget:

  * ``init(params0) -> state``        — fresh algorithm state from a params
    pytree (the state layout is algorithm-specific and opaque to callers),
  * ``round(state, data, key) -> (state, metrics)`` — one *server* round.
    ``data`` is the stacked per-client dataset pytree (leaves lead with an
    ``(n_clients, ...)`` axis); ``key`` is a jax PRNG key. Algorithms whose
    control flow is event-driven rather than SPMD (FedBuff) may keep python
    state and ignore ``key`` after the first call — the protocol promises
    determinism given ``init`` + the sequence of ``round`` keys, not
    jit-ability,
  * ``eval_params(state) -> params`` — the server model as a params pytree
    (what gets evaluated, checkpointed, and served).

**Metrics schema** — every ``round`` returns a dict containing at least
:data:`METRIC_KEYS`:

  ``sim_time``      cumulative simulated wall-clock after this round (s)
  ``round_time``    simulated duration of this round (s)
  ``bits_up``       client->server bits sent THIS round
  ``bits_down``     server->client bits sent THIS round
  ``h_steps_mean``  mean local SGD steps completed by the sampled clients
  ``quant_err``     mean relative quantization error of decoded uplink
                    messages (0.0 where nothing is quantized)

Bit counters follow the paper's accounting, which each algorithm's legacy
totals pin bit-for-bit: QuAFL's downlink Enc(X_t) is ONE broadcast message
(every sampled client decodes the same codes against its own model), while
FedAvg and FedBuff downlinks are per-client unicasts of the fp32 model
(s·d·32 resp. d·32 per restart) — the server model is the decode *payload*
there, not a shared code. Equal-bits comparisons inherit this convention.
The per-message sizes themselves are computed BY the selected codecs
(:mod:`repro.compression.codecs` — ``message_bits`` is the codec's WIRE
accounting, so word-aligned uint codes, sub-byte packed codes, and sparse
(index, value) messages all report what the interconnect actually moves).

Algorithms are free to add extra keys (``h_zero_frac``, ``c_norm``,
``bits_width``, ...); consumers that only rely on the schema keys stay
algorithm-agnostic. :func:`normalize_metrics` fills any missing schema key
with its documented default so downstream code can index unconditionally.

**Population & participation**: per-client state (client models, speeds,
EF residuals, control variates) lives in a :class:`repro.fed.population.
Population` store of stacked (n, ...) rows inside each algorithm's state;
rounds touch it through an O(s·row) gather/scatter of the participating
clients only, and WHO participates is a first-class ``Participation`` spec
on the clock (``uniform`` / ``gamma_straggler`` / ``cyclic:...``) — so
``n_clients`` sets memory, not per-round cost, and availability patterns
are a config axis rather than per-algorithm plumbing.

**Device-round capability** (optional): algorithms whose round body is pure
traced code — pytree state, device-scalar metrics with a fixed dict
structure, no host syncs — additionally expose ``device_round(state, data,
key)`` (see :class:`repro.fed.engine.DeviceFedAlgorithm`). The scanned
execution engine (``simulate(..., scan_chunk=K)``) runs such algorithms in
K-round ``lax.scan`` chunks with one host sync per chunk; everything else
falls back to the eager per-round loop.
"""
from __future__ import annotations

from typing import Any, Dict, Protocol, Tuple, runtime_checkable

METRIC_KEYS = ("sim_time", "round_time", "bits_up", "bits_down",
               "h_steps_mean", "quant_err")

_DEFAULTS = {"sim_time": 0.0, "round_time": 0.0, "bits_up": 0.0,
             "bits_down": 0.0, "h_steps_mean": 0.0, "quant_err": 0.0}


@runtime_checkable
class FedAlgorithm(Protocol):
    """Structural type every registered server algorithm satisfies."""

    def init(self, params0) -> Any:
        ...

    def round(self, state, data, key) -> Tuple[Any, Dict[str, Any]]:
        ...

    def eval_params(self, state) -> Any:
        ...


def normalize_metrics(metrics: Dict[str, Any]) -> Dict[str, float]:
    """Schema-complete, python-float view of a round's metrics dict.

    Missing schema keys get their documented defaults; every value is
    coerced with ``float`` (device scalars become host floats), extra keys
    are preserved when scalar-coercible and dropped otherwise.
    """
    out = dict(_DEFAULTS)
    for k, v in metrics.items():
        try:
            out[k] = float(v)
        except (TypeError, ValueError):
            continue  # non-scalar extras are not part of the trace format
    return out
