"""Million-client population engine: the sharded client-state store and
first-class ``Participation`` specs.

The paper's experiments stop at 300 LEAF nodes, but the whole point of a
compressed, partially-asynchronous FedAvg is that it survives *scale* — the
ROADMAP north-star asks the simulation itself to reach N=10^5..10^6 clients.
Two abstractions make N a **spec instead of a hot-path cost**:

  * **:class:`Population`** — ONE store for all per-client state: speeds λ,
    speed-class/phase-group labels, last-interaction times, client models,
    error-feedback/codec residuals, control variates. Every row is a stacked
    device array whose leading axis is the client axis, so the store is a
    plain pytree that rides ``jax.lax.scan`` carries, can be DONATED by the
    scanned engine, and shards over a client-parallel mesh axis
    (:func:`client_mesh` / :func:`shard_population`). A round touches the
    population only through a sparse :func:`gather_rows` of the s
    participating clients' rows and a :func:`scatter_rows` of the updated
    rows — both O(s·row), independent of N, and both INSIDE the traced round
    body so scanned rounds stay device-resident with one host sync per
    chunk.

  * **:class:`Participation`** — who enters a round is a spec on the clock,
    not an implementation detail of each algorithm:

      ``uniform``                      s clients uniformly without
                                       replacement (the paper's sampling).
      ``gamma_straggler[:strength=a]`` availability ∝ λ^a — fast clients
                                       answer polls more often (async-FL
                                       speedup regime of arXiv:2402.11198).
      ``cyclic:period=P,phase_groups=G``  the population is split into G
                                       contiguous phase groups; group
                                       ``(t // (P/G)) mod G`` is available
                                       during round t, and the s
                                       participants are drawn uniformly
                                       within it (periodic/cyclic
                                       participation à la Amplified
                                       SCAFFOLD, NeurIPS 2024).

    A spec is a pure function of ``(key, round t, n, s[, λ])`` — no state —
    so the schedule is deterministic across ``lax.scan`` chunk boundaries
    and identical between the eager and scanned engines.

**Per-client RNG is derived lazily** from ``(base_key, client_id)``
(:func:`client_keys`), generalizing the clock's lazy Poisson H-draws: a
client's randomness is a function of its IDENTITY, not of its position in
this round's sample or of where its row is sharded, so draws are stable
under resharding and under participation reordering. Non-uniform specs use
the per-client derivation for their H-draws (:meth:`Participation.h_steps`);
the ``uniform`` spec keeps the legacy batched draw bit-for-bit.

**Sampling cost.** ``jax.random.choice(..., replace=False)`` materializes an
O(N log N) permutation — 130ms/round at N=10^5 on CPU, which would make N a
hot-path cost again. Above :data:`DENSE_SAMPLE_MAX` clients the uniform
sampler switches to Floyd's algorithm (:func:`floyd_sample`): s tiny draws,
O(s^2) total, exact uniform without replacement. The switch is a pure
function of the static n, so both execution paths of any comparison see the
same draws; at small n the legacy ``jax.random.choice`` draw is preserved
bit-for-bit (the golden anchors run there).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, ClassVar, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.fed.clock import lazy_h_steps, speeds_for

# above this population size the uniform sampler switches from the legacy
# O(n log n) permutation draw to Floyd's O(s^2) subset sampler
DENSE_SAMPLE_MAX = 4096


# ---------------------------------------------------------------------------
# the store
# ---------------------------------------------------------------------------

class Population(NamedTuple):
    """All per-client state as stacked rows; leaves lead with the (n, ...)
    client axis. A plain pytree: scan-able, donat-able, shard-able."""
    rows: Dict[str, Any]

    @property
    def n(self) -> int:
        return jax.tree_util.tree_leaves(self.rows)[0].shape[0]

    def row(self, name: str):
        return self.rows[name]


def build_population(fed: FedConfig, n: int = None, *,
                     uniform_speeds: bool = False, lam=None,
                     **extra_rows) -> Population:
    """The base store: speeds ``lam`` (the clock's fast/slow split unless an
    explicit vector is given) and ``group`` speed-class labels (1 = slow),
    plus any algorithm-specific ``extra_rows`` (models, residuals, control
    variates, ...)."""
    n = fed.n_clients if n is None else n
    if lam is None:
        lam = speeds_for(fed, n, uniform=uniform_speeds)
    lam = jnp.asarray(lam, jnp.float32)
    group = (lam == jnp.float32(fed.lam_slow)).astype(jnp.int32)
    return Population(rows=dict(lam=lam, group=group, **extra_rows))


def with_rows(pop: Population, **rows) -> Population:
    """A copy of the store with the named rows added/replaced."""
    return Population(rows={**pop.rows, **rows})


def gather_rows(pop: Population, idx) -> Dict[str, Any]:
    """Sparse O(s·row) gather of the participating clients' rows."""
    return jax.tree_util.tree_map(lambda a: a[idx], pop.rows)


def scatter_rows(pop: Population, idx, updates: Dict[str, Any]) -> Population:
    """Scatter updated rows back (O(s·row)); untouched rows are carried
    through by reference so XLA keeps the store in place under donation."""
    new = dict(pop.rows)
    for name, val in updates.items():
        new[name] = jax.tree_util.tree_map(
            lambda a, v: a.at[idx].set(v), pop.rows[name], val)
    return Population(rows=new)


# ---------------------------------------------------------------------------
# client-parallel mesh axis
# ---------------------------------------------------------------------------

def client_mesh(devices=None):
    """A 1-D mesh over ``devices`` (default: all) with the client-parallel
    axis ``"clients"`` — orthogonal to the model-parallel ``data``/``model``
    axes of the SPMD path."""
    from jax.sharding import Mesh
    devices = jax.devices() if devices is None else devices
    return Mesh(np.asarray(devices), ("clients",))


def shard_population(pop: Population, mesh) -> Population:
    """Place every row with its leading client axis sharded over the mesh's
    ``"clients"`` axis (rows whose leading dim does not divide the axis stay
    replicated). The store's VALUES are unchanged — only placement moves."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    def place(a):
        spec = (P("clients") if a.ndim >= 1 and a.shape[0] % n_dev == 0
                else P())
        return jax.device_put(a, NamedSharding(mesh, spec))

    return Population(rows=jax.tree_util.tree_map(place, pop.rows))


# ---------------------------------------------------------------------------
# lazy per-client RNG
# ---------------------------------------------------------------------------

def client_keys(base_key, ids):
    """Key per client, derived lazily from ``(base_key, client_id)``.

    A client's stream is a function of its IDENTITY: the same ids yield the
    same keys regardless of sample order, round composition, or how the
    population rows are sharded — the generalization of the clock's lazy
    Poisson H-draw contract to every per-client random quantity."""
    return jax.vmap(lambda i: jax.random.fold_in(base_key, i))(
        jnp.asarray(ids, jnp.int32))


def lazy_h_steps_per_client(base_key, ids, lam_i, elapsed, local_steps: int):
    """Per-client-keyed variant of :func:`repro.fed.clock.lazy_h_steps`:
    H_i = min(K, Poisson(λ_i · elapsed_i)) drawn from ``fold_in(base, i)``,
    so a client's progress draw is stable under resharding and participation
    reordering (used by the non-uniform participation specs)."""
    ks = client_keys(base_key, ids)
    draws = jax.vmap(lambda k, rate: jax.random.poisson(k, rate))(
        ks, lam_i * elapsed)
    return jnp.minimum(draws, local_steps).astype(jnp.int32)


# ---------------------------------------------------------------------------
# samplers
# ---------------------------------------------------------------------------

def floyd_sample(key, n: int, s: int) -> jnp.ndarray:
    """Exact uniform s-subset of [0, n) without replacement in O(s^2) —
    Floyd's algorithm, unrolled over the (static, small) s. No O(n)
    permutation is ever materialized, so the draw cost is independent of
    the population size."""
    keys = jax.random.split(key, s)
    chosen = jnp.full((s,), -1, jnp.int32)
    for i in range(s):
        j = n - s + i
        t = jax.random.randint(keys[i], (), 0, j + 1, dtype=jnp.int32)
        dup = jnp.any(chosen == t)
        chosen = chosen.at[i].set(jnp.where(dup, jnp.int32(j), t))
    return chosen


def uniform_sample(key, n: int, s: int) -> jnp.ndarray:
    """Uniform without replacement, scale-aware: the legacy permutation
    draw (bit-for-bit ``clock.sample_clients``) up to
    :data:`DENSE_SAMPLE_MAX` clients, Floyd's O(s^2) sampler above. The
    switch depends only on the static n, so every execution path of a run
    at a given n sees identical draws."""
    if n <= DENSE_SAMPLE_MAX:
        return jax.random.choice(key, n, (s,), replace=False)
    return floyd_sample(key, n, s)


# ---------------------------------------------------------------------------
# participation specs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Participation:
    """Who participates in round t — a pure function of
    ``(key, t, n, s[, λ])``, deterministic across scan chunk boundaries.

    ``per_client_rng`` selects the H-draw derivation: False keeps the
    legacy batched draw (golden-pinned), True derives per-client keys via
    :func:`client_keys` (stable under resharding/reordering)."""

    per_client_rng: ClassVar[bool] = False
    name: ClassVar[str] = "base"

    def sample(self, key, t, n: int, s: int, lam=None) -> jnp.ndarray:
        raise NotImplementedError

    def h_steps(self, key, ids, lam_i, elapsed, local_steps: int):
        """Lazy local-progress draws for the sampled clients."""
        if self.per_client_rng:
            return lazy_h_steps_per_client(key, ids, lam_i, elapsed,
                                           local_steps)
        return lazy_h_steps(key, lam_i, elapsed, local_steps)


@dataclass(frozen=True)
class UniformParticipation(Participation):
    """The paper's sampling: s clients uniformly without replacement."""

    name: ClassVar[str] = "uniform"

    def sample(self, key, t, n: int, s: int, lam=None):
        return uniform_sample(key, n, s)


@dataclass(frozen=True)
class GammaStragglerParticipation(Participation):
    """Availability follows speed: P(client i enters) ∝ λ_i^strength —
    fast clients answer polls more often, slow clients drift longer between
    contacts (the heterogeneous-entry regime of arXiv:2402.11198). Exact
    weighted sampling without replacement via the Gumbel-top-k trick."""

    strength: float = 1.0
    per_client_rng: ClassVar[bool] = True
    name: ClassVar[str] = "gamma_straggler"

    def sample(self, key, t, n: int, s: int, lam=None):
        if lam is None:
            raise ValueError("gamma_straggler participation needs the "
                             "population's lam row")
        scores = (self.strength * jnp.log(lam)
                  + jax.random.gumbel(key, (n,)))
        return jax.lax.top_k(scores, s)[1].astype(jnp.int32)


@dataclass(frozen=True)
class CyclicParticipation(Participation):
    """Periodic availability à la Amplified SCAFFOLD (NeurIPS 2024): the
    population splits into ``phase_groups`` contiguous blocks; block
    ``(t // (period/phase_groups)) mod phase_groups`` is available during
    round t and the s participants are drawn uniformly within it. Every
    client's chance of participation over a full period window is equal —
    cyclic availability, not bias."""

    period: int = 8
    phase_groups: int = 4
    per_client_rng: ClassVar[bool] = True
    name: ClassVar[str] = "cyclic"

    def __post_init__(self):
        if self.phase_groups < 1 or self.period < self.phase_groups:
            raise ValueError(
                f"cyclic participation needs period >= phase_groups >= 1; "
                f"got period={self.period}, phase_groups={self.phase_groups}")
        if self.period % self.phase_groups:
            raise ValueError(
                f"cyclic period {self.period} must be a multiple of "
                f"phase_groups {self.phase_groups} (each group is available "
                f"for period/phase_groups consecutive rounds)")

    def rounds_per_phase(self) -> int:
        return self.period // self.phase_groups

    def group_at(self, t):
        """The phase group available during round t (traced-friendly)."""
        return (t // self.rounds_per_phase()) % self.phase_groups

    def sample(self, key, t, n: int, s: int, lam=None):
        G = self.phase_groups
        if n % G:
            raise ValueError(f"cyclic participation: n_clients {n} must be "
                             f"divisible by phase_groups {G}")
        m = n // G
        if s > m:
            raise ValueError(f"cyclic participation: s={s} exceeds the "
                             f"phase-group size {m} (= n/G = {n}/{G})")
        g = jnp.asarray(self.group_at(t), jnp.int32)
        return (g * m + uniform_sample(key, m, s)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# spec registry + parser (the same `name:key=val,...` grammar as the codec
# and transport registries)
# ---------------------------------------------------------------------------

_PARTICIPATIONS = {
    "uniform": UniformParticipation,
    "gamma_straggler": GammaStragglerParticipation,
    "cyclic": CyclicParticipation,
}


def registered_participations() -> Tuple[str, ...]:
    return tuple(_PARTICIPATIONS)


def register_participation(name: str, builder) -> None:
    """Register a custom availability pattern; ``builder(**params)`` must
    return a :class:`Participation`."""
    if name in _PARTICIPATIONS:
        raise ValueError(f"participation {name!r} already registered")
    _PARTICIPATIONS[name] = builder


def _parse_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    name, _, tail = spec.partition(":")
    params: Dict[str, Any] = {}
    if tail:
        for item in tail.split(","):
            k, eq, v = item.partition("=")
            if not eq or not k:
                raise ValueError(f"malformed participation spec {spec!r} "
                                 f"(want name:key=val,key=val)")
            try:
                params[k.strip()] = int(v)
            except ValueError:
                params[k.strip()] = float(v)
    return name.strip(), params


def resolve_participation(spec, fed: FedConfig = None) -> Participation:
    """Build a :class:`Participation` from a spec string (``"uniform"``,
    ``"gamma_straggler:strength=2"``, ``"cyclic:period=8,phase_groups=4"``),
    pass an instance through, or — given ``None``/``""`` — fall back to
    ``fed.participation`` and finally to ``uniform``."""
    if isinstance(spec, Participation):
        return spec
    if spec is None or spec == "":
        spec = getattr(fed, "participation", "") or "uniform"
        if isinstance(spec, Participation):
            return spec
    if not isinstance(spec, str):
        raise TypeError(f"participation spec must be a name string or "
                        f"Participation instance; got {type(spec).__name__}")
    name, params = _parse_spec(spec)
    if name not in _PARTICIPATIONS:
        raise ValueError(f"unknown participation {name!r}; choose from "
                         f"{sorted(_PARTICIPATIONS)}")
    return _PARTICIPATIONS[name](**params)
