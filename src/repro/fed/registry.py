"""String registry of federated server algorithms.

``make_algorithm(name, fed, loss_fn=..., template=..., batch_fn=...)``
constructs any server variant in the repo behind the ONE
:class:`repro.fed.FedAlgorithm` protocol, so drivers select algorithms by
name (``launch/train.py --algo``, benchmark sweeps, the ``compare()``
harness) instead of hand-wiring a class per experiment:

  ``quafl``           paper Alg. 1 (async polling + lattice-quantized
                      exchange); kwargs: ``avg_mode``, ``uniform_speeds``,
                      ``exchange_impl``
  ``fedavg``          synchronous FedAvg (waits for stragglers,
                      uncompressed); kwargs: ``uniform_speeds``
  ``fedbuff``         buffered asynchronous aggregation; kwargs:
                      ``buffer_size``, ``server_lr``, ``quantize``,
                      ``quantizer``, ``uniform_speeds``
  ``sequential``      single slow node, one step per round (paper Fig. 3)
  ``quafl_scaffold``  QuAFL + SCAFFOLD control variates (beyond-paper);
                      QuAFL kwargs
  ``adaptive_quafl``  QuAFL under the adaptive bit-width controller
                      (beyond-paper); kwargs: ``lo``, ``hi``, ``b_min``,
                      ``b_max``
  ``fedbuff_device``  FedBuff with its event state as a pure pytree
                      (device ring buffer, jit/scan-able rounds); FedBuff
                      kwargs plus ``completion_table`` (seed bridge)
  ``spmd``            the mesh-sharded QuAFL train step behind the
                      protocol (one client per mesh data slice); kwargs:
                      ``cfg`` (ModelConfig, REQUIRED), ``mesh``, ``batch``,
                      ``seq``, ``fed_mode``, ``transport``, ``remat``
  ``compressed_fedavg``  FedPAQ-family compressed synchronous FedAvg
                      (arXiv:2106.07155, arXiv:2308.08165) built purely
                      from the codec API; kwargs: ``server_lr``,
                      ``uniform_speeds``

Every algorithm that communicates additionally accepts ``uplink=`` /
``downlink=`` codec specs (:mod:`repro.compression.codecs` — names like
``"lattice_packed"``, ``"scalar:bits=4"``, or a ``{"fast": ..., "slow":
...}`` per-client group map), defaulting to ``FedConfig.codec_up`` /
``codec_down`` and then to the algorithm's historical scheme; the metrics'
``bits_up`` / ``bits_down`` are computed by the selected codecs.

The round-sampling algorithms (``quafl``, ``fedavg``, ``quafl_scaffold``,
``adaptive_quafl``, ``compressed_fedavg``) also accept ``participation=``
— a :mod:`repro.fed.population` spec (``"uniform"``,
``"gamma_straggler:strength=2"``, ``"cyclic:period=8,phase_groups=4"``, or
a ``Participation`` instance) selecting WHO answers each round's poll,
defaulting to ``FedConfig.participation`` and then uniform — and
``client_mesh=`` to shard the per-client population store over a
client-parallel mesh axis. ``fedbuff``/``fedbuff_device`` are event-driven
(every client completion arrives; there is no per-round draw to re-spec),
and ``sequential``/``spmd`` have no sampled cohort.

The registry is extensible: third-party variants join via
:func:`register_algorithm` and immediately work with ``simulate()`` /
``compare()`` and every registry-driven entry point.

Core modules are imported lazily inside the builders — ``repro.core``
imports ``repro.fed.clock``, so eager imports here would be circular.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

from repro.configs.base import FedConfig
from repro.fed.api import FedAlgorithm


def _build_quafl(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.quafl import QuAFL
    return QuAFL(fed=fed, loss_fn=loss_fn, template=template,
                 batch_fn=batch_fn, **kw)


def _build_fedavg(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.fedavg import FedAvg
    return FedAvg(fed=fed, loss_fn=loss_fn, template=template,
                  batch_fn=batch_fn, **kw)


def _build_fedbuff(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.fedbuff import FedBuff
    return FedBuff(fed=fed, loss_fn=loss_fn, template=template,
                   batch_fn=batch_fn, **kw)


def _build_sequential(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.baseline import Sequential
    return Sequential(fed=fed, loss_fn=loss_fn, template=template,
                      batch_fn=batch_fn, **kw)


def _build_scaffold(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.extensions import QuaflScaffold
    return QuaflScaffold(fed=fed, loss_fn=loss_fn, template=template,
                         batch_fn=batch_fn, **kw)


def _build_adaptive(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.extensions import AdaptiveQuaflAlgorithm
    from repro.core.quafl import QuAFL
    quafl_kw = {k: kw.pop(k) for k in ("avg_mode", "uniform_speeds",
                                       "exchange_impl", "uplink", "downlink",
                                       "participation", "client_mesh")
                if k in kw}

    def make_alg(f):
        return QuAFL(fed=f, loss_fn=loss_fn, template=template,
                     batch_fn=batch_fn, **quafl_kw)

    return AdaptiveQuaflAlgorithm(fed, make_alg, **kw)


def _build_fedbuff_device(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.fedbuff import FedBuffDevice
    return FedBuffDevice(fed=fed, loss_fn=loss_fn, template=template,
                         batch_fn=batch_fn, **kw)


def _build_compressed_fedavg(fed, loss_fn, template, batch_fn, **kw):
    from repro.core.fedavg import CompressedFedAvg
    return CompressedFedAvg(fed=fed, loss_fn=loss_fn, template=template,
                            batch_fn=batch_fn, **kw)


def _build_spmd(fed, loss_fn, template, batch_fn, **kw):
    # loss_fn/batch_fn are protocol-uniform arguments the mesh path does not
    # consume: the train step hardwires the LM loss and samples minibatches
    # from the token-pool `data` itself.
    from repro.launch.spmd import SpmdAlgorithm
    return SpmdAlgorithm(fed=fed, template=template, **kw)


_BUILDERS: Dict[str, Callable[..., FedAlgorithm]] = {
    "quafl": _build_quafl,
    "fedavg": _build_fedavg,
    "fedbuff": _build_fedbuff,
    "sequential": _build_sequential,
    "quafl_scaffold": _build_scaffold,
    "adaptive_quafl": _build_adaptive,
    "fedbuff_device": _build_fedbuff_device,
    "spmd": _build_spmd,
    "compressed_fedavg": _build_compressed_fedavg,
}


def registered_algorithms() -> Tuple[str, ...]:
    """Names accepted by :func:`make_algorithm`, in registration order."""
    return tuple(_BUILDERS)


def register_algorithm(name: str,
                       builder: Callable[..., FedAlgorithm]) -> None:
    """Register a custom server variant. ``builder`` receives
    ``(fed, loss_fn, template, batch_fn, **kwargs)`` and must return a
    :class:`repro.fed.FedAlgorithm`."""
    if name in _BUILDERS:
        raise ValueError(f"algorithm {name!r} already registered")
    _BUILDERS[name] = builder


def make_algorithm(name: str, fed: FedConfig, *, loss_fn, template,
                   batch_fn, **kwargs) -> FedAlgorithm:
    """Build the named server algorithm behind the unified protocol.

    ``loss_fn(params_pytree, batch) -> (loss, aux)``; ``template`` is the
    params pytree the flat optimization vectors unflatten against;
    ``batch_fn(client_data, key) -> batch`` samples one client minibatch.
    Algorithm-specific ``kwargs`` are forwarded (see module docstring).
    """
    if name not in _BUILDERS:
        raise ValueError(f"unknown algorithm {name!r}; choose from "
                         f"{sorted(_BUILDERS)}")
    return _BUILDERS[name](fed, loss_fn, template, batch_fn, **kwargs)
