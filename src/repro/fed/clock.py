"""Shared simulation clock: client speeds, lazy H-step draws, stragglers,
and buffered arrivals.

Every server variant in the paper's comparison (§5, App. A) runs against the
same client-speed model — per-step durations are Exp(λ_i) with λ chosen by a
fast/slow split — but each algorithm observes that clock differently:

  * **QuAFL** polls s clients per round and lazily replays the
    ``min(K, Poisson(λ_i · elapsed))`` local steps each would have completed
    since its last interaction (App. B.1: unsampled clients' steps have no
    observable effect, so they are drawn at poll time),
  * **FedAvg** waits for the slowest sampled client: the round takes
    ``max_i Gamma(K, λ_i)`` plus the server interaction time,
  * **FedBuff** is event-driven: each client finishes its K steps after a
    ``Gamma(K, λ_i)`` duration and its arrival lands in a shared buffer.

This module is the single home for all three observations — previously the
plumbing was copy-pasted across ``core/quafl.py``, ``core/fedavg.py`` and
``core/fedbuff.py``. Functions are numerically identical to the originals
(same distributions, same key usage), so seeded runs are unchanged.

WHO answers a poll is the clock's fourth observation: a first-class
``Participation`` spec (``uniform`` — bit-for-bit :func:`sample_clients` —
``gamma_straggler``, ``cyclic:period=P,phase_groups=G``) living in
:mod:`repro.fed.population` with the sharded per-client state store; the
spec names are re-exported here so clock-level code can resolve them
without importing the store."""
from __future__ import annotations

import heapq
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig


# ---------------------------------------------------------------------------
# client speed model (paper App. A)
# ---------------------------------------------------------------------------

def client_speeds(fed: FedConfig, n: int) -> np.ndarray:
    """λ per client: first ``slow_frac``·n clients are slow (paper App. A:
    step time ~ Exp(λ), λ=1/2 fast, λ=1/8 slow, 30% slow)."""
    lam = np.full(n, fed.lam_fast, dtype=np.float32)
    n_slow = int(round(fed.slow_frac * n))
    lam[:n_slow] = fed.lam_slow
    return lam


def speeds_for(fed: FedConfig, n: int, uniform: bool = False) -> np.ndarray:
    """Speed vector, optionally forcing every client to the fast rate."""
    if uniform:
        return np.full(n, fed.lam_fast, np.float32)
    return client_speeds(fed, n)


def expected_steps(fed: FedConfig, lam: np.ndarray) -> np.ndarray:
    """H_i = E[steps between interactions], capped at K. Between interactions
    a client has ≈ n/s · (swt+sit) time in expectation."""
    elapsed = (fed.swt + fed.sit) * max(fed.n_clients / fed.s, 1.0)
    return np.minimum(fed.local_steps, np.maximum(lam * elapsed, 1e-3))


# ---------------------------------------------------------------------------
# QuAFL-style polling: sampling + lazy H-step replay counts
# ---------------------------------------------------------------------------

def sample_clients(key, n: int, s: int) -> jnp.ndarray:
    """The round's polled-client index set (uniform, without replacement)."""
    return jax.random.choice(key, n, (s,), replace=False)


def lazy_h_steps(key, lam, elapsed, local_steps: int) -> jnp.ndarray:
    """H_i^t = min(K, Poisson(λ_i · elapsed_i)) — the number of Exp(λ_i)-
    duration steps client i would have completed since its last interaction
    (drawn lazily at poll time, App. B.1). May be 0: the client is polled
    mid-flight with no progress and still participates (paper §2.2)."""
    return jnp.minimum(jax.random.poisson(key, lam * elapsed),
                       local_steps).astype(jnp.int32)


def straggler_round_time(key, lam, local_steps: int, sit: float):
    """Synchronous round duration: the slowest sampled client's K-step
    Gamma(K, λ_i) duration plus the server interaction time (FedAvg)."""
    s = lam.shape[0]
    steps = jax.random.gamma(key, local_steps * jnp.ones((s,))) / lam
    return jnp.max(steps) + sit


# ---------------------------------------------------------------------------
# FedBuff-style buffered arrivals (event-driven, numpy rng)
# ---------------------------------------------------------------------------

def completion_time(rng: np.random.Generator, local_steps: int,
                    lam: float) -> float:
    """Duration of one client's K local steps: Gamma(K, 1/λ)."""
    return float(rng.gamma(local_steps, 1.0 / lam))


def completion_time_device(key, local_steps: int, lam) -> jnp.ndarray:
    """Device-side formulation of :func:`completion_time` — the same
    Gamma(K, 1/λ) distribution drawn from a jax key, usable inside a traced
    round body (``repro.core.fedbuff.FedBuffDevice``). The jax and numpy
    streams differ draw-for-draw; use the seed bridge
    (:func:`repro.fed.engine.fedbuff_completion_table`) when bit-for-bit
    agreement with the legacy event stream is required."""
    return jax.random.gamma(key, jnp.asarray(local_steps, jnp.float32)) / lam


class ArrivalQueue:
    """Min-heap of (finish_time, client) completion events.

    The buffered-asynchronous server pops arrivals in time order; each pop
    is immediately followed by a :meth:`push` rescheduling the client's next
    completion. Pure container — all randomness comes from the caller's rng
    through :func:`completion_time`, preserving the legacy event order.
    """

    def __init__(self, events: List[Tuple[float, int]] = None):
        self.events: List[Tuple[float, int]] = list(events or [])
        heapq.heapify(self.events)

    @classmethod
    def initial(cls, rng: np.random.Generator, lam: np.ndarray,
                local_steps: int) -> ArrivalQueue:
        q = cls()
        for i in range(len(lam)):
            q.push(completion_time(rng, local_steps, lam[i]), i)
        return q

    def push(self, t: float, client: int):
        heapq.heappush(self.events, (t, client))

    def pop(self) -> Tuple[float, int]:
        return heapq.heappop(self.events)

    def peek(self) -> Tuple[float, int]:
        return self.events[0]

    def __len__(self):
        return len(self.events)

    def copy(self) -> ArrivalQueue:
        return ArrivalQueue(self.events)


# ---------------------------------------------------------------------------
# participation specs (canonical home: repro.fed.population — lazily
# re-exported here to keep clock -> population import-free; population
# imports the speed model above)
# ---------------------------------------------------------------------------

_PARTICIPATION_NAMES = ("Participation", "UniformParticipation",
                       "GammaStragglerParticipation", "CyclicParticipation",
                       "resolve_participation", "registered_participations")


def __getattr__(name: str):
    if name in _PARTICIPATION_NAMES:
        from repro.fed import population
        return getattr(population, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
