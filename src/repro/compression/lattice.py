"""Position-aware lattice quantizer (Davies et al. [7], Lemma 3.1).

Semantics (paper §2.2): ``Enc(x)`` maps x to b-bit codes; ``Dec(y, Enc(x))``
recovers Q(x) using any reference y with ``‖x − y‖`` small. Practical
construction: randomized Hadamard rotation + *modulo* uniform quantization —
the codes are the stochastically-rounded rotated coordinates mod 2^b, and the
decoder snaps to the representative nearest its own rotated reference. The
three Lemma 3.1 properties hold whenever the wrap condition is met:

  1. unbiased decoding   E[Q(x)] = x      (stochastic rounding)
  2. error bound         ‖Q(x) − x‖ ≤ γ·sqrt(d_pad)        (ℓ∞ ≤ γ)
  3. bit cost            d·b + O(1) bits; b ~ log(‖x−y‖/γ)

γ is chosen from a *distance hint* the encoder always has locally (the client
knows ‖Y − X^i‖ = η·η_i·‖h̃‖; the server uses its previous round delta), so
the error is proportional to the model *distance*, never the model norm —
this is exactly what makes direct QSGD-style quantization unsound here
(paper §2.2 'Fully-Quantized Communication').
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.rotation import DEFAULT_BLOCK, pad_len, rotate


class LatticeMsg(NamedTuple):
    codes: jnp.ndarray     # (d_pad,) unsigned ints in [0, 2^b)
    gamma: jnp.ndarray     # () fp32 — transmitted scale (O(1) overhead)


@dataclass(frozen=True)
class LatticeQuantizer:
    bits: int = 8
    block: int = DEFAULT_BLOCK
    safety: float = 8.0    # head-room factor on the wrap window

    @property
    def levels(self) -> int:
        return 1 << self.bits

    def code_dtype(self):
        if self.bits <= 8:
            return jnp.uint8
        if self.bits <= 16:
            return jnp.uint16
        return jnp.uint32

    # -- γ from the encoder-local distance hint ----------------------------
    def gamma_for(self, dist_hint: jnp.ndarray, d: int) -> jnp.ndarray:
        """dist_hint: upper estimate of ‖x − ref‖₂. After rotation the
        difference coordinates are subgaussian with scale dist/sqrt(d); the
        wrap window 2^b·γ must exceed twice the max coordinate."""
        d_pad = pad_len(d, self.block)
        maxcoord = dist_hint / np.sqrt(d_pad) * (np.sqrt(2 * np.log(2 * d_pad + 1)) + 2.0)
        gamma = self.safety * 2.0 * maxcoord / self.levels
        return jnp.maximum(gamma, 1e-12)

    # -- Enc ----------------------------------------------------------------
    def encode(self, key, x: jnp.ndarray, dist_hint) -> LatticeMsg:
        """x: flat (d,) fp32. key: shared rotation+rounding key for the
        interaction (the server's round seed — both ends derive it)."""
        d = x.shape[0]
        gamma = self.gamma_for(jnp.asarray(dist_hint, jnp.float32), d)
        krot, krnd = jax.random.split(key)
        y = rotate(x, krot, self.block)
        # fp32 precision floor: the modulo decode needs y/γ (and w/γ) to keep
        # sub-integer precision, so γ ≥ max|y|·2^-18. When the distance hint
        # is tiny relative to the model norm the error bound degrades to the
        # model's own fp32 resolution instead of silently mis-decoding.
        gamma = jnp.maximum(gamma, jnp.max(jnp.abs(y)) * 2.0 ** -18)
        u = jax.random.uniform(krnd, y.shape, jnp.float32)
        q = jnp.floor(y / gamma + u)             # stochastic rounding
        codes = jnp.mod(q, self.levels).astype(self.code_dtype())
        return LatticeMsg(codes=codes, gamma=gamma)

    # -- Dec(ref, msg) -------------------------------------------------------
    def decode(self, key, msg: LatticeMsg, ref: jnp.ndarray) -> jnp.ndarray:
        """ref: flat (d,) decoding key (paper's y). Returns Q(x) of len d."""
        d = ref.shape[0]
        krot, _ = jax.random.split(key)
        w = rotate(ref, krot, self.block)        # rotated reference
        codes = msg.codes.astype(jnp.float32)
        # nearest integer to w/γ congruent to codes (mod 2^b)
        q = codes + self.levels * jnp.round((w / msg.gamma - codes)
                                            / self.levels)
        xr = q * msg.gamma
        x = rotate(xr, krot, self.block, inverse=True)
        return x[:d]

    # -- exact bit accounting (Lemma 3.8) ------------------------------------
    def message_bits(self, d: int) -> int:
        return pad_len(d, self.block) * self.bits + 32  # + γ scalar


@dataclass(frozen=True)
class QSGDQuantizer:
    """Standard norm-scaled stochastic quantizer [Alistarh et al., 1]. Not
    position-aware: error ∝ ‖x‖ (used as the paper's Figure-5 baseline)."""
    bits: int = 8
    block: int = DEFAULT_BLOCK  # unused; uniform API

    @property
    def levels(self) -> int:
        return (1 << (self.bits - 1)) - 1  # signed levels

    def encode(self, key, x: jnp.ndarray, dist_hint=None):
        norm = jnp.linalg.norm(x) + 1e-12
        y = jnp.abs(x) / norm * self.levels
        u = jax.random.uniform(key, x.shape, jnp.float32)
        q = jnp.floor(y + u) * jnp.sign(x)
        return LatticeMsg(codes=q.astype(jnp.int32), gamma=norm)

    def decode(self, key, msg: LatticeMsg, ref=None):
        return msg.codes.astype(jnp.float32) * (msg.gamma / self.levels)

    def message_bits(self, d: int) -> int:
        return d * self.bits + 32


@dataclass(frozen=True)
class IdentityQuantizer:
    bits: int = 32

    def encode(self, key, x, dist_hint=None):
        return LatticeMsg(codes=x, gamma=jnp.float32(1.0))

    def decode(self, key, msg, ref=None):
        return msg.codes

    def message_bits(self, d: int) -> int:
        return d * 32


def make_quantizer(name: str, bits: int):
    if name == "lattice":
        return LatticeQuantizer(bits=bits)
    if name == "qsgd":
        return QSGDQuantizer(bits=bits)
    if name == "none":
        return IdentityQuantizer()
    raise ValueError(name)
