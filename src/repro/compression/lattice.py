"""Position-aware lattice quantizer (Davies et al. [7], Lemma 3.1).

Semantics (paper §2.2): ``Enc(x)`` maps x to b-bit codes; ``Dec(y, Enc(x))``
recovers Q(x) using any reference y with ``‖x − y‖`` small. Practical
construction: randomized Hadamard rotation + *modulo* uniform quantization —
the codes are the stochastically-rounded rotated coordinates mod 2^b, and the
decoder snaps to the representative nearest its own rotated reference. The
three Lemma 3.1 properties hold whenever the wrap condition is met:

  1. unbiased decoding   E[Q(x)] = x      (stochastic rounding)
  2. error bound         ‖Q(x) − x‖ ≤ γ·sqrt(d_pad)        (ℓ∞ ≤ γ)
  3. bit cost            d·b + O(1) bits; b ~ log(‖x−y‖/γ)

γ is chosen from a *distance hint* the encoder always has locally (the client
knows ‖Y − X^i‖ = η·η_i·‖h̃‖; the server uses its previous round delta), so
the error is proportional to the model *distance*, never the model norm —
this is exactly what makes direct QSGD-style quantization unsound here
(paper §2.2 'Fully-Quantized Communication').

The encode/decode math itself lives in the compression *pipeline* backend
registry (repro.compression.pipeline): ``backend="jnp"`` composes pure-jnp
ops, ``"pallas_interpret"``/``"pallas"`` run the fused Pallas kernels
(rotate+round+wrap in one pass; rotate-ref+snap+inverse-rotate in one pass).
The quantizer is a thin per-message wrapper that fixes the wire format
(``LatticeMsg``) and the key schedule (split -> rotation key, rounding key).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compression.rotation import DEFAULT_BLOCK, _signs, pad_len
from repro.compression.pipeline import (GAMMA_NORM_FLOOR, coord_bound,
                                        get_backend, wrap_gamma)


class LatticeMsg(NamedTuple):
    codes: jnp.ndarray     # (d_pad,) unsigned ints in [0, 2^b)
    gamma: jnp.ndarray     # () fp32 — transmitted scale (O(1) overhead)


@dataclass(frozen=True)
class LatticeQuantizer:
    bits: int = 8
    block: int = DEFAULT_BLOCK
    safety: float = 8.0    # head-room factor on the wrap window
    backend: str = "jnp"   # pipeline backend running the actual math

    @property
    def levels(self) -> int:
        return 1 << self.bits

    def code_dtype(self):
        if self.bits <= 8:
            return jnp.uint8
        if self.bits <= 16:
            return jnp.uint16
        return jnp.uint32

    def _ops(self):
        return get_backend(self.backend)

    # -- γ from the encoder-local distance hint ----------------------------
    def gamma_for(self, dist_hint: jnp.ndarray, d: int) -> jnp.ndarray:
        """dist_hint: upper estimate of ‖x − ref‖₂. After rotation the
        difference coordinates are subgaussian with scale dist/sqrt(d); the
        wrap window 2^b·γ must exceed twice the max coordinate."""
        return wrap_gamma(dist_hint, d, bits=self.bits, block=self.block,
                          safety=self.safety)

    # -- Enc ----------------------------------------------------------------
    def encode(self, key, x: jnp.ndarray, dist_hint) -> LatticeMsg:
        """x: flat (d,) fp32. key: shared rotation+rounding key for the
        interaction (the server's round seed — both ends derive it)."""
        d = x.shape[0]
        d_pad = pad_len(d, self.block)
        gamma = self.gamma_for(jnp.asarray(dist_hint, jnp.float32), d)
        # fp32 precision floor: the modulo decode needs y/γ (and w/γ) to
        # keep sub-integer precision, so γ ≥ max|rot(x)|·2^-18. The max
        # rotated coordinate is estimated pre-rotation from the (rotation-
        # invariant) norm so γ is available before the fused rotate+quantize
        # kernel runs. When the distance hint is tiny relative to the model
        # norm the error bound degrades to the model's own fp32 resolution
        # instead of silently mis-decoding.
        gamma = jnp.maximum(gamma, coord_bound(jnp.linalg.norm(x), d_pad)
                            * GAMMA_NORM_FLOOR)
        krot, krnd = jax.random.split(key)
        signs = _signs(krot, d_pad)
        u = jax.random.uniform(krnd, (d_pad,), jnp.float32)
        x2 = jnp.pad(x.astype(jnp.float32), (0, d_pad - d))[None]
        codes = self._ops().encode(x2, signs, u[None], gamma[None],
                                   bits=self.bits, block=self.block,
                                   want_rotated=False)[0]
        return LatticeMsg(codes=codes.astype(self.code_dtype()), gamma=gamma)

    # -- Dec(ref, msg) -------------------------------------------------------
    def decode(self, key, msg: LatticeMsg, ref: jnp.ndarray) -> jnp.ndarray:
        """ref: flat (d,) decoding key (paper's y). Returns Q(x) of len d.

        One fused pass: rotate the reference, snap each code to the
        representative nearest the reference coordinate, inverse-rotate."""
        d = ref.shape[0]
        d_pad = pad_len(d, self.block)
        krot, _ = jax.random.split(key)
        signs = _signs(krot, d_pad)
        ref2 = jnp.pad(ref.astype(jnp.float32), (0, d_pad - d))[None]
        x = self._ops().decode(msg.codes[None], ref2, signs,
                               jnp.reshape(msg.gamma, (1,)), bits=self.bits,
                               block=self.block)[0]
        return x[:d]

    # -- exact bit accounting (Lemma 3.8) ------------------------------------
    def message_bits(self, d: int) -> int:
        return pad_len(d, self.block) * self.bits + 32  # + γ scalar


@dataclass(frozen=True)
class QSGDQuantizer:
    """Standard norm-scaled stochastic quantizer [Alistarh et al., 1]. Not
    position-aware: error ∝ ‖x‖ (used as the paper's Figure-5 baseline)."""
    bits: int = 8
    block: int = DEFAULT_BLOCK  # unused; uniform API

    @property
    def levels(self) -> int:
        return (1 << (self.bits - 1)) - 1  # signed levels

    def encode(self, key, x: jnp.ndarray, dist_hint=None):
        norm = jnp.linalg.norm(x) + 1e-12
        y = jnp.abs(x) / norm * self.levels
        u = jax.random.uniform(key, x.shape, jnp.float32)
        q = jnp.floor(y + u) * jnp.sign(x)
        return LatticeMsg(codes=q.astype(jnp.int32), gamma=norm)

    def decode(self, key, msg: LatticeMsg, ref=None):
        return msg.codes.astype(jnp.float32) * (msg.gamma / self.levels)

    def message_bits(self, d: int) -> int:
        return d * self.bits + 32


@dataclass(frozen=True)
class IdentityQuantizer:
    bits: int = 32

    def encode(self, key, x, dist_hint=None):
        return LatticeMsg(codes=x, gamma=jnp.float32(1.0))

    def decode(self, key, msg, ref=None):
        return msg.codes

    def message_bits(self, d: int) -> int:
        return d * 32


def make_quantizer(name: str, bits: int, backend: str = "jnp"):
    if name == "lattice":
        return LatticeQuantizer(bits=bits, backend=backend)
    if name == "qsgd":
        return QSGDQuantizer(bits=bits)
    if name == "none":
        return IdentityQuantizer()
    raise ValueError(name)
