"""Error-feedback (EF14/EF21-style) compression — the alternative the paper
REJECTS (§2.2): transmitting quantized updates with a client-side error
accumulator needs extra memory at the client and second-moment assumptions;
the position-aware lattice quantizer needs neither. Implemented so the
trade-off is runnable (see bench_quantizer tracking ablation and
tests/test_error_feedback.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Tuple

import jax.numpy as jnp

from repro.compression.lattice import LatticeMsg, QSGDQuantizer


class EFState(NamedTuple):
    error: jnp.ndarray     # client-side residual memory (d,)


@dataclass(frozen=True)
class ErrorFeedbackQSGD:
    """QSGD on (delta + carried error); the un-transmitted residual is
    remembered and re-injected next round."""
    bits: int = 8

    def init(self, d: int) -> EFState:
        return EFState(error=jnp.zeros((d,), jnp.float32))

    def compress(self, key, delta: jnp.ndarray,
                 state: EFState) -> Tuple[LatticeMsg, jnp.ndarray, EFState]:
        """Returns (message, decoded value at the server, new client state).

        QSGD is not a contraction for small bits / large d (variance bound
        ω = √d/levels can exceed 1), so the decoded value is scaled by the
        standard 1/(1+ω) to keep the EF recursion stable."""
        import numpy as np
        q = QSGDQuantizer(bits=self.bits)
        target = delta + state.error
        msg = q.encode(key, target)
        omega = np.sqrt(delta.shape[0]) / q.levels
        decoded = q.decode(key, msg) / (1.0 + omega)
        return msg, decoded, EFState(error=target - decoded)

    def message_bits(self, d: int) -> int:
        return QSGDQuantizer(bits=self.bits).message_bits(d)
