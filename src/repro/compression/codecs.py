"""Composable codec API: pluggable compression, per direction, per client.

The paper composes three system relaxations — data heterogeneity,
asynchrony, and compression — but a compression scheme is ONE point in a
large design space (lattice vs. scalar stochastic rounding vs.
sparsification; 1..32 bits; packed vs. word-aligned wire formats). This
module makes the scheme a first-class, registry-selected object so every
algorithm in :mod:`repro.fed` takes ``uplink=`` / ``downlink=`` codec specs
instead of hard-wiring one quantizer:

**Codec protocol** — ``encode(key, x, hint) -> msg``,
``decode(key, msg, ref) -> x̂``, and ``message_bits(d)`` /
``bits_per_coord(d)`` (the WIRE accounting every algorithm's ``bits_up`` /
``bits_down`` metrics are computed from). ``hint`` is the encoder-local
distance estimate (position-aware codecs derive their scale from it;
others ignore it); ``ref`` is the decoder-side reference. Codecs carrying
cross-round encoder state (error feedback) set ``stateful = True`` and
implement ``init_state(d)`` + ``encode_stateful(key, x, hint, state) ->
(msg, state)``; algorithms that thread the state get error feedback,
everything else falls back to the stateless ``encode``.

**Registry** (mirroring the ``FedAlgorithm`` registry):

  ``lattice``         position-aware lattice quantizer (the paper's
                      default; unchanged math, word-aligned uint codes on
                      the wire — so 4-bit codes still ship 8 bits/coord)
  ``lattice_packed``  same math, sub-byte packed wire: ``8 // bits`` codes
                      per byte, packed inside the fused encode kernel and
                      unpacked in snap/decode (bits ∈ {1, 2, 4, 8})
  ``topk_ef``         position-aware top-k sparsification + error
                      feedback: transmit the k largest-|·| coordinates
                      (plus the carried residual when the algorithm threads
                      state); untransmitted coordinates decode to the
                      reference
  ``scalar``          FedPAQ/QSGD-style norm-scaled stochastic rounding
                      (NOT position-aware: error ∝ ‖x‖ — the §2.2 baseline)
  ``identity``        fp32 pass-through (32 bits/coord, no γ overhead)

Specs are strings — ``"lattice"``, ``"scalar:bits=4"``,
``"topk_ef:frac=0.05"`` — codec instances, or (uplink only) a
``{"fast": spec, "slow": spec}`` group map resolved against the client
speed classes into a :class:`GroupedLatticeCodec` with per-client bit
budgets (fast clients at b=8, stragglers at b=4 is one config knob).
Third-party codecs join via :func:`register_codec` and immediately work
with every registry algorithm, ``simulate()``, and the launch drivers
(``--codec-up`` / ``--codec-down``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.provenance import wire_mark
from repro.compression.lattice import (IdentityQuantizer, LatticeMsg,
                                       LatticeQuantizer, QSGDQuantizer)
from repro.compression.pipeline import LatticeWire
from repro.compression.rotation import DEFAULT_BLOCK, pad_len
from repro.kernels.exchange import pack_codes, unpack_codes


@runtime_checkable
class Codec(Protocol):
    """Structural type of a registered compression codec."""

    def encode(self, key, x, hint) -> Any:
        ...

    def decode(self, key, msg, ref) -> Any:
        ...

    def message_bits(self, d: int) -> int:
        ...


# ---------------------------------------------------------------------------
# machine-readable wire declarations
# ---------------------------------------------------------------------------

class WirePart(NamedTuple):
    """One named component of a codec's per-message wire format.

    ``elems``/``container_bits`` describe what a trace must show at the
    matching ``wire_mark`` site (the physical value crossing the wire);
    ``charged_bits`` is this part's contribution to ``message_bits(d)``.
    The two may legitimately differ per coordinate (``scalar`` charges its
    entropy-coded b bits while shipping a whole int container), but a
    payload charged sub-16-bit that traces as a >= 32-bit container is a
    wire lie the audit rejects.
    """
    part: str             # "codes" | "idx" | "vals" | "gamma" | "levels"
    elems: int            # per-message element count on the wire
    container_bits: int   # traced dtype width at the wire_mark site
    charged_bits: int     # contribution to message_bits(d)
    kind: str             # "int" | "float"
    payload: bool         # coordinate payload vs. 32-bit side-channel row


class WireDecl(NamedTuple):
    """A codec's declared wire format, consumed by ``analysis/wire.py``.

    Replaces the prose convention ("lattice ships packed codes plus a γ
    scalar...") with data the gate can cross-check against traces:
    ``moduli`` are the wrap moduli the γ-overflow interval analysis must
    prove safe (empty for non-lattice codecs), ``safety`` the declared
    head-room factor of the wrap window.
    """
    codec: str
    parts: Tuple[WirePart, ...]
    moduli: Tuple[int, ...] = ()
    safety: float = 0.0

    @property
    def message_bits(self) -> int:
        return sum(p.charged_bits for p in self.parts)

    def part(self, name: str) -> WirePart | None:
        for p in self.parts:
            if p.part == name:
                return p
        return None

    @property
    def side_rows(self) -> Tuple[str, ...]:
        return tuple(p.part for p in self.parts if not p.payload)


class CodecBase:
    """Shared defaults: stateless, derived per-coordinate accounting."""
    stateful: bool = False
    # error-feedback residuals are the un-decoded remainder of the message,
    # which the encoder can only compute when it knows what the decoder
    # reconstructs — i.e. for DELTA-style messages decoded against the zero
    # vector. Algorithms whose uplink decodes against a non-zero reference
    # (QuAFL's model-vs-server exchange) must use the stateless encode.
    ef_zero_ref_only: bool = True

    def init_state(self, d: int):
        return ()

    def encode_stateful(self, key, x, hint, state):
        """Stateless fallback: EF-capable algorithms thread ``state``;
        everything else calls plain ``encode`` and the codec degrades
        gracefully (no residual memory)."""
        return self.encode(key, x, hint), state

    def bits_per_coord(self, d: int) -> float:
        return self.message_bits(d) / d


def init_client_states(codec, n: int, d: int):
    """Stacked per-client encoder state of a stateful codec (``()`` for
    stateless ones) — the shared helper behind every algorithm that
    threads error-feedback residuals."""
    if not codec.stateful:
        return ()
    st0 = codec.init_state(d)
    return jax.tree_util.tree_map(
        lambda a: jnp.tile(a[None], (n,) + (1,) * a.ndim), st0)


# ---------------------------------------------------------------------------
# identity / scalar — thin codec views of the legacy quantizers
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class IdentityCodec(CodecBase):
    """fp32 pass-through; the 'uncompressed' point of the design space."""
    name: str = "identity"
    bits: int = 32

    def encode(self, key, x, hint=None):
        msg = IdentityQuantizer().encode(key, x, hint)
        return LatticeMsg(
            codes=wire_mark(msg.codes, channel="msg", part="codes",
                            codec=self.name, d=int(x.shape[-1])),
            gamma=msg.gamma)

    def decode(self, key, msg, ref=None):
        return msg.codes

    def message_bits(self, d: int) -> int:
        return d * 32

    def wire_declaration(self, d: int) -> WireDecl:
        return WireDecl(codec=self.name, parts=(
            WirePart("codes", d, 32, d * 32, "float", True),))


@dataclass(frozen=True)
class ScalarCodec(CodecBase):
    """FedPAQ-style norm-scaled stochastic rounding (arXiv:2106.07155's
    quantizer; the paper's Figure-5 'direct quantization' baseline). Not
    position-aware — ``ref`` is ignored and the error scales with ‖x‖."""
    bits: int = 8
    name: str = "scalar"

    def __post_init__(self):
        object.__setattr__(self, "quant", QSGDQuantizer(bits=self.bits))

    def _container(self):
        # signed storage of levels in [-(2^(b-1)-1), 2^(b-1)-1]
        return jnp.int8 if self.bits <= 8 else (
            jnp.int16 if self.bits <= 16 else jnp.int32)

    def encode(self, key, x, hint=None):
        msg = self.quant.encode(key, x, hint)
        # wire container honesty: the signed levels fit the b-bit int dtype;
        # the legacy int32 working dtype is not what the wire would move
        codes = wire_mark(msg.codes.astype(self._container()), channel="msg",
                          part="codes", codec=self.name, d=int(x.shape[-1]))
        gamma = wire_mark(msg.gamma, channel="msg", part="gamma",
                          codec=self.name, d=int(x.shape[-1]))
        return LatticeMsg(codes=codes, gamma=gamma)

    def decode(self, key, msg, ref=None):
        return self.quant.decode(key, msg, ref)

    def message_bits(self, d: int) -> int:
        return self.quant.message_bits(d)

    def wire_declaration(self, d: int) -> WireDecl:
        return WireDecl(codec=self.name, parts=(
            WirePart("codes", d, _storage_bits(self.bits), d * self.bits,
                     "int", True),
            WirePart("gamma", 1, 32, 32, "float", False)))


# ---------------------------------------------------------------------------
# lattice family
# ---------------------------------------------------------------------------

def _storage_bits(bits: int) -> int:
    """Wire width of one unpacked lattice code: the uint dtype that holds
    2^bits levels (what the interconnect actually moves — see
    ``LatticeQuantizer.code_dtype``)."""
    return 8 if bits <= 8 else (16 if bits <= 16 else 32)


@dataclass(frozen=True)
class LatticeCodec(CodecBase):
    """Position-aware lattice quantizer as a codec.

    ``packed=False`` ships word-aligned uint codes (8/16/32 bits per
    coordinate — the historical wire format, and the honest accounting of
    it); ``packed=True`` is the ``lattice_packed`` registry entry: sub-byte
    packing inside the fused encode kernel, exactly ``bits`` bits per
    coordinate on the wire (requires ``bits`` ∈ {1, 2, 4, 8}). The math is
    identical either way (pack ∘ unpack is the identity), so at b=8 the two
    codecs coincide and both reproduce the PR 3 exchange bit for bit.
    """
    bits: int = 8
    block: int = DEFAULT_BLOCK
    safety: float = 8.0
    backend: str = "jnp"
    packed: bool = False
    name: str = "lattice"
    family: str = "lattice"

    def __post_init__(self):
        if self.packed and self.bits not in (1, 2, 4, 8):
            raise ValueError(
                f"lattice_packed needs bits in {{1, 2, 4, 8}} (whole codes "
                f"per byte); got bits={self.bits}")
        object.__setattr__(self, "quant", LatticeQuantizer(
            bits=self.bits, block=self.block, safety=self.safety,
            backend=self.backend))

    @property
    def pack(self) -> int:
        return (8 // self.bits) if self.packed else 1

    def wire(self, idx=None) -> LatticeWire:
        """The fused-pipeline wire descriptor of this codec (``idx``, the
        sampled-client index set, only matters for grouped codecs)."""
        return LatticeWire(bits=self.bits, pack=self.pack)

    # -- per-message API (generic paths, mesh leaves, FedBuff deltas) ------
    def encode(self, key, x, hint):
        msg = self.quant.encode(key, x, hint)
        if self.pack > 1:
            codes = pack_codes(msg.codes[None].astype(jnp.uint32),
                               bits=self.bits, block=self.block)[0]
            msg = LatticeMsg(codes=codes, gamma=msg.gamma)
        return LatticeMsg(
            codes=wire_mark(msg.codes, channel="msg", part="codes",
                            codec=self.name, d=int(x.shape[-1])),
            gamma=wire_mark(msg.gamma, channel="msg", part="gamma",
                            codec=self.name, d=int(x.shape[-1])))

    def decode(self, key, msg, ref):
        if self.pack > 1:
            codes = unpack_codes(msg.codes[None], bits=self.bits,
                                 block=self.block)[0]
            msg = LatticeMsg(codes=codes.astype(self.quant.code_dtype()),
                             gamma=msg.gamma)
        return self.quant.decode(key, msg, ref)

    def message_bits(self, d: int) -> int:
        per = self.bits if self.packed else _storage_bits(self.bits)
        return pad_len(d, self.block) * per + 32  # + γ scalar

    def code_dtype(self):
        return jnp.uint8 if self.pack > 1 else self.quant.code_dtype()

    def wire_declaration(self, d: int) -> WireDecl:
        dp = pad_len(d, self.block)
        per = self.bits if self.packed else _storage_bits(self.bits)
        # packed wire: d_pad/pack uint8 containers each holding `pack`
        # codes; unpacked: d_pad containers at the storage width
        container = 8 if self.packed else _storage_bits(self.bits)
        return WireDecl(codec=self.name, parts=(
            WirePart("codes", dp // self.pack, container, dp * per,
                     "int", True),
            WirePart("gamma", 1, 32, 32, "float", False)),
            moduli=(1 << self.bits,), safety=self.safety)


@dataclass(frozen=True)
class GroupedLatticeCodec(CodecBase):
    """Heterogeneous per-client bit budgets over the lattice exchange.

    ``bits_per_client`` assigns each client its own bit-width; the fused
    rotated-space pipeline runs ONE batched exchange with per-message wrap
    moduli (``LatticeWire.levels``), so a round can mix b=8 fast clients
    with b=4 stragglers at no extra rotation passes. Runs on every kernel
    backend — the Pallas kernels take the moduli as a lane-aligned levels
    row next to the γ rows. Uplink only (the downlink broadcast is one
    message).

    Wire accounting is the MEMBER codec's: ``wire_width_per_client[i]`` is
    the bits/coordinate the client's group declared — ``lattice`` members
    charge their word-aligned uint storage, ``lattice_packed`` members
    exactly their sub-byte width (each client's message is uniform-width,
    so per-message packing is well defined even though the batched
    pipeline computes on unpacked working arrays).
    """
    bits_per_client: Tuple[int, ...]
    wire_width_per_client: Tuple[int, ...]   # bits/coord on the wire
    block: int = DEFAULT_BLOCK
    safety: float = 8.0
    backend: str = "jnp"
    name: str = "lattice_grouped"
    family: str = "lattice"
    packed: bool = False

    def __post_init__(self):
        assert len(self.wire_width_per_client) == len(self.bits_per_client)
        object.__setattr__(self, "bits", int(max(self.bits_per_client)))
        object.__setattr__(self, "_levels_j", jnp.asarray(
            [1 << int(b) for b in self.bits_per_client], jnp.float32))
        object.__setattr__(self, "quant", LatticeQuantizer(
            bits=self.bits, block=self.block, safety=self.safety,
            backend=self.backend))

    @property
    def pack(self) -> int:
        return 1

    def wire(self, idx=None) -> LatticeWire:
        """Wire descriptor for the sampled client subset ``idx``."""
        levels = self._levels_j if idx is None else self._levels_j[idx]
        return LatticeWire(bits=self.bits, pack=1, levels=levels)

    def message_bits(self, d: int) -> int:
        # + γ scalar + the per-message wrap modulus (levels row): the
        # receiver cannot snap a heterogeneous-width message without its
        # modulus, so the row is charged wire traffic, not an exempt
        # side channel (it is audited via wire_declaration like any part)
        return (pad_len(d, self.block) * max(self.wire_width_per_client)
                + 64)

    def message_bits_per_client(self, d: int) -> np.ndarray:
        dp = pad_len(d, self.block)
        return np.asarray([dp * int(w) + 64
                           for w in self.wire_width_per_client], np.float32)

    def bits_for(self, idx, d: int):
        """Traced total uplink bits of the sampled subset ``idx``."""
        mb = jnp.asarray(self.message_bits_per_client(d))
        return jnp.sum(mb[idx])

    def wire_declaration(self, d: int) -> WireDecl:
        dp = pad_len(d, self.block)
        w_max = max(self.wire_width_per_client)
        return WireDecl(codec=self.name, parts=(
            WirePart("codes", dp, _storage_bits(self.bits), dp * w_max,
                     "int", True),
            WirePart("gamma", 1, 32, 32, "float", False),
            WirePart("levels", 1, 32, 32, "float", False)),
            moduli=tuple(sorted({1 << int(b)
                                 for b in self.bits_per_client})),
            safety=self.safety)

    # per-message API: encode/decode one client's message at ITS bit-width
    # is not expressible with a shared jit cache — the grouped codec exists
    # for the batched pipeline path. Fall back to max-bits messages.
    def encode(self, key, x, hint):
        return self.quant.encode(key, x, hint)

    def decode(self, key, msg, ref):
        return self.quant.decode(key, msg, ref)


# ---------------------------------------------------------------------------
# top-k sparsification + error feedback
# ---------------------------------------------------------------------------

class TopKMsg(NamedTuple):
    idx: jnp.ndarray    # (k,) int32 coordinate indices
    vals: jnp.ndarray   # (k,) f32 transmitted values


@dataclass(frozen=True)
class TopKEFCodec(CodecBase):
    """Position-aware top-k: ship the k largest-magnitude coordinates;
    every untransmitted coordinate decodes to the REFERENCE value, so the
    per-message error is bounded by ‖x − ref‖ restricted to the dropped
    support (and by ‖x‖ against a zero reference — the classic sparse-delta
    case). With threaded state (EF14/EF21 style, cf.
    ``repro.compression.error_feedback``) the untransmitted residual is
    remembered encoder-side and re-injected next round, so every coordinate
    is eventually transmitted. The residual equals ``target`` off the
    transmitted support — the encoding error ONLY when the decoder
    reconstructs zero there (``ef_zero_ref_only``): delta-style uplinks
    (FedBuff, compressed FedAvg) thread it; model-vs-server exchanges fall
    back to the stateless encode."""
    frac: float = 0.01      # fraction of coordinates transmitted
    k_min: int = 1
    name: str = "topk_ef"
    stateful: bool = True
    ef_zero_ref_only: bool = True

    def k_for(self, d: int) -> int:
        return max(self.k_min, int(round(self.frac * d)))

    def init_state(self, d: int):
        return jnp.zeros((d,), jnp.float32)

    def _encode(self, target):
        k = self.k_for(target.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(target), k)
        idx = idx.astype(jnp.int32)
        d = int(target.shape[0])
        return TopKMsg(
            idx=wire_mark(idx, channel="msg", part="idx", codec=self.name,
                          d=d),
            vals=wire_mark(target[idx], channel="msg", part="vals",
                           codec=self.name, d=d))

    def encode(self, key, x, hint=None):
        return self._encode(x.astype(jnp.float32))

    def encode_stateful(self, key, x, hint, state):
        target = x.astype(jnp.float32) + state
        msg = self._encode(target)
        return msg, target.at[msg.idx].set(0.0)

    def decode(self, key, msg, ref):
        return ref.astype(jnp.float32).at[msg.idx].set(msg.vals)

    def message_bits(self, d: int) -> int:
        return self.k_for(d) * (32 + 32)  # (index, value) pairs

    def wire_declaration(self, d: int) -> WireDecl:
        k = self.k_for(d)
        return WireDecl(codec=self.name, parts=(
            WirePart("idx", k, 32, k * 32, "int", True),
            WirePart("vals", k, 32, k * 32, "float", True)))


# ---------------------------------------------------------------------------
# registry + spec resolution
# ---------------------------------------------------------------------------

def _build_lattice(*, bits, backend, block, safety, packed=False, **kw):
    _reject_extra(kw, "lattice")
    return LatticeCodec(bits=bits, block=block, safety=safety,
                        backend=backend, packed=packed,
                        name="lattice_packed" if packed else "lattice")


def _build_lattice_packed(**kw):
    return _build_lattice(packed=True, **kw)


def _build_scalar(*, bits, backend, block, safety, **kw):
    _reject_extra(kw, "scalar")
    return ScalarCodec(bits=bits)


def _build_identity(*, bits, backend, block, safety, **kw):
    _reject_extra(kw, "identity")
    return IdentityCodec()


def _build_topk_ef(*, bits, backend, block, safety, frac=0.01, **kw):
    _reject_extra(kw, "topk_ef")
    return TopKEFCodec(frac=float(frac))


def _reject_extra(kw: Dict[str, Any], name: str):
    if kw:
        raise ValueError(f"unknown codec parameter(s) {sorted(kw)} for "
                         f"{name!r}")


_CODECS: Dict[str, Any] = {
    "lattice": _build_lattice,
    "lattice_packed": _build_lattice_packed,
    "topk_ef": _build_topk_ef,
    "scalar": _build_scalar,
    "identity": _build_identity,
}

# FedConfig.quantizer legacy names -> codec registry names
_LEGACY_QUANTIZER = {"lattice": "lattice", "qsgd": "scalar",
                     "none": "identity"}


def registered_codecs() -> Tuple[str, ...]:
    """Names accepted by :func:`make_codec`, in registration order."""
    return tuple(_CODECS)


def register_codec(name: str, builder) -> None:
    """Register a custom codec. ``builder`` receives keyword arguments
    ``bits``, ``backend``, ``block``, ``safety`` plus any ``name:key=val``
    spec parameters, and must return a :class:`Codec`."""
    if name in _CODECS:
        raise ValueError(f"codec {name!r} already registered")
    _CODECS[name] = builder


def _parse_spec(spec: str) -> Tuple[str, Dict[str, Any]]:
    """'name' or 'name:k=v,k=v' -> (name, {k: parsed_v})."""
    name, _, tail = spec.partition(":")
    params: Dict[str, Any] = {}
    if tail:
        for item in tail.split(","):
            k, _, v = item.partition("=")
            if not _ or not k:
                raise ValueError(f"malformed codec spec {spec!r} "
                                 f"(want name:key=val,key=val)")
            try:
                params[k.strip()] = int(v)
            except ValueError:
                params[k.strip()] = float(v)
    return name.strip(), params


def make_codec(spec, *, bits: int = 8, backend: str = "jnp",
               block: int = DEFAULT_BLOCK, safety: float = 8.0) -> Codec:
    """Build a codec from a spec string (or pass a codec instance through).

    ``bits`` / ``backend`` / ``block`` / ``safety`` are the config-derived
    defaults; a ``bits=`` in the spec string overrides the config value.
    """
    if not isinstance(spec, str):
        if isinstance(spec, Codec):
            return spec
        raise TypeError(f"codec spec must be a name string or codec "
                        f"instance (group dicts resolve through "
                        f"resolve_codec); got {type(spec).__name__}")
    name, params = _parse_spec(spec)
    if name not in _CODECS:
        raise ValueError(f"unknown codec {name!r}; choose from "
                         f"{sorted(_CODECS)}")
    bits = int(params.pop("bits", bits))
    safety = float(params.pop("safety", safety))
    block = int(params.pop("block", block))
    return _CODECS[name](bits=bits, backend=backend, block=block,
                         safety=safety, **params)


def resolve_codec(spec, fed, *, direction: str, default: str = None,
                  slow_mask=None) -> Codec:
    """Resolve an algorithm's per-direction codec.

    Precedence: explicit ``spec`` kwarg > ``fed.codec_up`` /
    ``fed.codec_down`` > ``default`` > the legacy ``fed.quantizer`` map
    (lattice | qsgd→scalar | none→identity). A dict spec
    ``{"fast": ..., "slow": ...}`` (uplink only) resolves each group and
    combines lattice-family members into a :class:`GroupedLatticeCodec`
    over ``slow_mask`` (the boolean per-client straggler mask from the
    clock's speed model).
    """
    backend = getattr(fed, "kernel_backend", "jnp")
    if spec is None:
        spec = getattr(fed, f"codec_{direction}", "") or None
    if spec is None:
        spec = default or _LEGACY_QUANTIZER.get(fed.quantizer)
        if spec is None:
            raise ValueError(f"no codec mapping for quantizer "
                             f"{fed.quantizer!r}")
    if isinstance(spec, dict):
        if direction != "up":
            raise ValueError("per-client group codecs apply to the uplink "
                             "only (the downlink is one broadcast message)")
        if slow_mask is None:
            raise ValueError("group codec specs need the algorithm's "
                             "client speed classes (slow_mask)")
        members = {g: make_codec(s, bits=fed.bits, backend=backend)
                   for g, s in spec.items()}
        unknown = set(members) - {"fast", "slow"}
        if unknown:
            raise ValueError(f"unknown client groups {sorted(unknown)}; "
                             f"use 'fast' / 'slow'")
        fast = members.get("fast")
        slow = members.get("slow", fast)
        fast = fast if fast is not None else slow
        if not all(isinstance(c, LatticeCodec) for c in (fast, slow)):
            raise NotImplementedError(
                "per-client group codecs currently compose lattice-family "
                "members only")
        if (fast.safety, fast.block) != (slow.safety, slow.block):
            raise ValueError("group members must share safety/block (one "
                             "batched exchange, one γ derivation)")

        def width(c: LatticeCodec) -> int:
            # the member's own declared wire: packed members charge their
            # sub-byte width, unpacked ones their uint storage
            return c.bits if c.packed else _storage_bits(c.bits)

        mask = np.asarray(slow_mask)
        bits = tuple(int(slow.bits) if bool(m) else int(fast.bits)
                     for m in mask)
        widths = tuple(width(slow) if bool(m) else width(fast)
                       for m in mask)
        return GroupedLatticeCodec(bits_per_client=bits,
                                   wire_width_per_client=widths,
                                   block=fast.block, safety=fast.safety,
                                   backend=backend)
    return make_codec(spec, bits=fed.bits, backend=backend)


def is_lattice_family(codec) -> bool:
    """True when the fused rotated-space pipeline can carry this codec."""
    return getattr(codec, "family", "") == "lattice"
