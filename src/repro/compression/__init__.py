from repro.compression.lattice import (IdentityQuantizer, LatticeMsg,  # noqa: F401
                                       LatticeQuantizer, QSGDQuantizer,
                                       make_quantizer)
from repro.compression.pipeline import (BACKENDS, Backend,  # noqa: F401
                                        ExchangePipeline, LatticeWire,
                                        get_backend, wrap_gamma)
from repro.compression.codecs import (Codec, GroupedLatticeCodec,  # noqa: F401
                                      IdentityCodec, LatticeCodec,
                                      ScalarCodec, TopKEFCodec,
                                      is_lattice_family, make_codec,
                                      register_codec, registered_codecs,
                                      resolve_codec)
from repro.compression.transports import (Transport,  # noqa: F401
                                          make_transport,
                                          register_transport,
                                          registered_transports,
                                          transport_for_mode)
from repro.compression.rotation import rotate, pad_len  # noqa: F401
