from repro.compression.lattice import (IdentityQuantizer, LatticeMsg,  # noqa: F401
                                       LatticeQuantizer, QSGDQuantizer,
                                       make_quantizer)
from repro.compression.pipeline import (BACKENDS, Backend,  # noqa: F401
                                        ExchangePipeline, RotationStats,
                                        get_backend, wrap_gamma)
from repro.compression.rotation import rotate, pad_len  # noqa: F401
