"""Randomized Hadamard rotation (the practical lattice quantizer of
Davies et al. [7] is 'a random rotation followed by direct quantization').

The rotation is applied blockwise: the flat vector is padded to a multiple of
``block`` (a power of two) and each block is multiplied by Q = H_b D / sqrt(b)
with D a Rademacher diagonal. We express H_b as H_r ⊗ H_c (b = r*c) so the
transform is two small dense matmuls — on TPU these hit the MXU directly
(a butterfly FWHT is VPU-bound); the Pallas kernel in repro.kernels/hadamard
implements exactly this decomposition. Q is orthogonal and symmetric up to
the sign diagonal, so the inverse is D H_b / sqrt(b).
"""
from __future__ import annotations

from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = 16_384  # 128 x 128


@lru_cache(maxsize=None)
def hadamard_matrix(n: int) -> np.ndarray:
    """Sylvester construction; n must be a power of two."""
    assert n & (n - 1) == 0, n
    h = np.array([[1.0]], dtype=np.float32)
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return h


def _factor(block: int):
    k = int(np.log2(block))
    r = 1 << ((k + 1) // 2)
    c = 1 << (k // 2)
    assert r * c == block
    return r, c


def _block_size(d: int, block: int) -> int:
    b = 1
    while b < min(d, block):
        b <<= 1
    return b


def pad_len(d: int, block: int = DEFAULT_BLOCK) -> int:
    b = _block_size(d, block)
    return int(np.ceil(d / b)) * b


def _signs(key, n):
    return jax.random.rademacher(key, (n,), dtype=jnp.float32)


def rotate(x: jnp.ndarray, key, block: int = DEFAULT_BLOCK,
           inverse: bool = False) -> jnp.ndarray:
    """x: flat (d,) float32 -> rotated, padded to a block multiple.

    forward:  y = (H x*s) / sqrt(b)   (per block)
    inverse:  x = (H y) / sqrt(b) * s
    The caller keeps the padded length; ``unpad`` with [:d].
    """
    d = x.shape[0]
    b = _block_size(d, block)
    padded = pad_len(d, block)
    x = jnp.pad(x.astype(jnp.float32), (0, padded - d))
    s = _signs(key, padded)
    r, c = _factor(b)
    hr = jnp.asarray(hadamard_matrix(r))
    hc = jnp.asarray(hadamard_matrix(c))
    scale = 1.0 / np.sqrt(b)
    if not inverse:
        x = x * s
    blocks = x.reshape(-1, r, c)
    # (H_r ⊗ H_c) vec(X) == H_r @ X @ H_c^T  (H_c symmetric)
    y = jnp.einsum("ij,bjk,kl->bil", hr, blocks, hc) * scale
    y = y.reshape(-1)
    if inverse:
        y = y * s
    return y
