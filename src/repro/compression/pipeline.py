"""Compression pipeline: rotated-space quantized exchange + backend registry.

This is the subsystem behind every production use of the position-aware
lattice quantizer. It has two layers:

**Backend registry** — the four primitive ops of the exchange (batched
randomized-Hadamard ``rotate``, fused rotate+stochastic-round+wrap
``encode``, rotated-space positional ``snap``, and the fully fused
``decode``) exist in three interchangeable implementations:

  * ``"jnp"``             — pure-jnp einsum composition (XLA fuses what it
                            can; the CPU-CI default),
  * ``"pallas_interpret"`` — the Pallas kernels from ``repro.kernels.
                            exchange`` run through the interpreter, so CPU CI
                            validates the exact code path a TPU executes,
  * ``"pallas"``          — the same kernels compiled for a real TPU.

Select per experiment with ``FedConfig.kernel_backend``; all backends share
one ``gamma`` derivation so messages are interchangeable across them.

**Rotated-space exchange** (``ExchangePipeline.quafl_round``) — the QuAFL
round restructured so every vector is rotated at most once. All messages in
a round share one rotation key (the paper already assumes shared
per-interaction keys; sharing across the round's messages is equally valid
because the rotation is orthogonal), so encode/decode/averaging all happen
in rotated coordinates and only the final server/client states are
inverse-rotated. Per round with ``s`` sampled clients this costs exactly

  * ``s + 1`` forward rotations  — the s client messages (fused with their
    encode) and the server's rotation (the uplink decode reference). The
    server's own Enc(X_t) needs no rotation pass: its γ depends on the
    decoded uplink so it cannot fold into the srv_rot pass, but the cached
    rotated coords make it a pure elementwise quantize
    (``Backend.quantize`` — stochastic round + wrap, no Hadamard work),
  * ``s + 1`` inverse rotations — the s new client states + the new server
    state, rotated back only after averaging,

down from the seed composition's ``5s + 1`` full-model rotation passes (and
the first fused version's ``s + 2`` forward). A trace-time counter
(:class:`repro.analysis.opbudget.OpBudget`, exposed as ``pipeline.stats``)
audits this invariant in the tests and the ``repro.analysis.lint`` gate.

The downlink decode reference is the client's **current** model Y^i (the
model it holds when the reply arrives) rather than its pre-round state X^i;
both satisfy the Lemma 3.1 wrap condition and Y^i is already resident in
rotated space, which is what removes the per-client reference rotations.

``quafl_round_reference`` is the materialize-everything per-message
composition over the *same* key/noise/γ derivation — the equivalence oracle
for the fused path (tests assert fp32-level agreement on full rounds).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.provenance import wire_mark
from repro.compression.rotation import (DEFAULT_BLOCK, _signs,
                                        hadamard_matrix, pad_len)
from repro.kernels.exchange import (block_geometry, fused_decode,
                                    fused_encode, fused_rotate, pack_codes,
                                    quantize_codes, snap_codes, unpack_codes)

BACKENDS = ("jnp", "pallas_interpret", "pallas")


class LatticeWire(NamedTuple):
    """Per-direction wire parametrization of the lattice exchange.

    ``bits`` is the static bit-width (kernel wrap/pack parameter);
    ``pack = 8 // bits`` ships that many codes per byte (the
    ``lattice_packed`` codec; 1 = historical unpacked layout); ``levels``
    optionally carries PER-MESSAGE quantization levels (a (m,) f32 array of
    powers of two <= 2^bits) for heterogeneous per-client bit budgets —
    supported by every backend: the Pallas kernels take the moduli as a
    lane-aligned levels row riding next to the γ rows.
    """
    bits: int
    pack: int = 1
    levels: Any = None

def wire_container_dtype(wire: LatticeWire):
    """The uint dtype one wire code physically ships in (packed wires hold
    ``pack`` codes per uint8 byte)."""
    if wire.pack > 1 or wire.bits <= 8:
        return jnp.uint8
    return jnp.uint16 if wire.bits <= 16 else jnp.uint32


def observe_lattice_wire(codes, gammas, wire: LatticeWire, channel: str):
    """Record the wire form of a lattice message batch for the wire-truth
    audit: dead-code casts + identity marks that XLA eliminates, but that
    stay visible in the traced jaxpr. The leading axis is the message
    batch."""
    d = int(codes.shape[-1]) * max(int(wire.pack), 1)
    wire_mark(codes.astype(wire_container_dtype(wire)), channel=channel,
              part="codes", codec="wire", batched=True, d=d)
    wire_mark(jnp.asarray(gammas, jnp.float32).reshape(-1), channel=channel,
              part="gamma", codec="wire", batched=True, d=d)
    if wire.levels is not None:
        wire_mark(jnp.asarray(wire.levels, jnp.float32).reshape(-1),
                  channel=channel, part="levels", codec="wire", batched=True,
                  d=d)


# fp32 precision floor: the modulo decode needs y/γ (and w/γ) to keep
# sub-integer precision, so γ must not drop below max|rot(x)|·2^-18. The
# fused encode needs γ BEFORE the rotation runs, so we bound max|rot(x)|
# by the same subgaussian coordinate estimate the wrap window uses
# (rotated coordinates have scale ‖x‖/sqrt(d_pad)) — the floor keeps the
# seed's ~‖x‖·polylog/sqrt(d)·2^-18 scale instead of a loose ‖x‖·2^-18.
GAMMA_NORM_FLOOR = 2.0 ** -18


# ---------------------------------------------------------------------------
# shared gamma derivation (identical across backends)
# ---------------------------------------------------------------------------

def coord_bound(norms, d_pad: int):
    """High-probability bound on the max rotated coordinate of a vector
    with the given l2 norm (subgaussian scale norm/sqrt(d_pad))."""
    return (jnp.asarray(norms, jnp.float32) / np.sqrt(d_pad)
            * (np.sqrt(2 * np.log(2 * d_pad + 1)) + 2.0))


def wrap_gamma(dist_hint, d: int, *, bits: int = None, levels=None,
               block: int = DEFAULT_BLOCK, safety: float = 8.0):
    """Per-message lattice scale from the encoder-local distance hint.

    After rotation the difference coordinates are subgaussian with scale
    dist/sqrt(d_pad); the wrap window 2^b·γ must exceed twice the max
    coordinate. Vectorized over ``dist_hint``; ``levels`` (scalar or a
    per-message array, default ``1 << bits``) supports heterogeneous
    bit-widths within one batched call.
    """
    if levels is None:
        levels = 1 << bits
    d_pad = pad_len(d, block)
    gamma = safety * 2.0 * coord_bound(dist_hint, d_pad) / levels
    return jnp.maximum(gamma, 1e-12)


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

class Backend(NamedTuple):
    """The five primitive ops; every op is batched over a message axis.

    The quantizing ops additionally take ``pack`` (sub-byte packed codes,
    :mod:`repro.kernels.exchange` layout) and ``levels2`` (optional
    per-message quantization levels for heterogeneous bit budgets — on the
    Pallas backends the moduli ride as a lane-aligned levels row).
    """
    name: str
    rotate: Callable    # (x2, signs, *, block, inverse) -> y2
    encode: Callable    # (x2, signs, u2, gammas, *, bits, block,
                        #  want_rotated, pack, levels2)
                        #  -> codes | (rotated, codes)
    quantize: Callable  # (y2_rotated, u2, gammas, *, bits, block, pack,
                        #  levels2) -> codes
    snap: Callable      # (codes2, wrot2, gammas, *, bits, block, pack,
                        #  levels2) -> q2
    decode: Callable    # (codes2, ref2, signs, gammas, *, bits, block,
                        #  pack, levels2) -> x2


def _levels_jnp(bits, levels2):
    """The wrap modulus: the static 2^bits, or per-message (m, 1) rows."""
    if levels2 is None:
        return 1 << bits
    return jnp.asarray(levels2, jnp.float32).reshape(-1, 1)


def _rotate_jnp(x2, signs, *, block=DEFAULT_BLOCK, inverse=False):
    m, d_pad = x2.shape
    b, _, r, c, nb = block_geometry(d_pad, block)
    hr = jnp.asarray(hadamard_matrix(r))
    hc = jnp.asarray(hadamard_matrix(c))
    x = x2.astype(jnp.float32)
    if not inverse:
        x = x * signs[None, :]
    y = jnp.einsum("ij,bjk,kl->bil", hr, x.reshape(m * nb, r, c),
                   hc) * (1.0 / np.sqrt(b))
    y = y.reshape(m, d_pad)
    if inverse:
        y = y * signs[None, :]
    return y


def _encode_jnp(x2, signs, u2, gammas, *, bits=8, block=DEFAULT_BLOCK,
                want_rotated=False, pack=1, levels2=None):
    y = _rotate_jnp(x2, signs, block=block)
    g = jnp.asarray(gammas, jnp.float32).reshape(-1, 1)
    codes = jnp.mod(jnp.floor(y / g + u2),
                    _levels_jnp(bits, levels2)).astype(jnp.uint32)
    if pack > 1:
        codes = pack_codes(codes, bits=bits, block=block)
    return (y, codes) if want_rotated else codes


def _quantize_jnp(y2, u2, gammas, *, bits=8, block=DEFAULT_BLOCK, pack=1,
                  levels2=None):
    g = jnp.asarray(gammas, jnp.float32).reshape(-1, 1)
    codes = jnp.mod(jnp.floor(y2.astype(jnp.float32) / g + u2),
                    _levels_jnp(bits, levels2)).astype(jnp.uint32)
    if pack > 1:
        codes = pack_codes(codes, bits=bits, block=block)
    return codes


def _snap_jnp(codes2, wrot2, gammas, *, bits=8, block=DEFAULT_BLOCK, pack=1,
              levels2=None):
    if pack > 1:
        codes2 = unpack_codes(codes2, bits=bits, block=block)
    levels = _levels_jnp(bits, levels2)
    cc = codes2.astype(jnp.float32)
    g = jnp.asarray(gammas, jnp.float32).reshape(-1, 1)
    q = cc + levels * jnp.round((wrot2 / g - cc) / levels)
    return q * g


def _decode_jnp(codes2, ref2, signs, gammas, *, bits=8, block=DEFAULT_BLOCK,
                pack=1, levels2=None):
    w = _rotate_jnp(ref2, signs, block=block)
    xr = _snap_jnp(codes2, w, gammas, bits=bits, block=block, pack=pack,
                   levels2=levels2)
    return _rotate_jnp(xr, signs, block=block, inverse=True)


def _pallas_backend(name: str, interpret: bool) -> Backend:
    return Backend(
        name=name,
        rotate=partial(fused_rotate, interpret=interpret),
        encode=partial(fused_encode, interpret=interpret),
        quantize=partial(quantize_codes, interpret=interpret),
        snap=partial(snap_codes, interpret=interpret),
        decode=partial(fused_decode, interpret=interpret),
    )


_REGISTRY = {
    "jnp": Backend("jnp", _rotate_jnp, _encode_jnp, _quantize_jnp, _snap_jnp,
                   _decode_jnp),
    "pallas_interpret": _pallas_backend("pallas_interpret", interpret=True),
    "pallas": _pallas_backend("pallas", interpret=False),
}


def get_backend(name: str) -> Backend:
    if name not in _REGISTRY:
        raise ValueError(
            f"unknown kernel backend {name!r}; choose from {BACKENDS}")
    return _REGISTRY[name]


# ---------------------------------------------------------------------------
# rotation audit counter (trace-time: counts are structural, not data-dep.)
# ---------------------------------------------------------------------------
# The counter class itself lives in repro.analysis.opbudget (promoted from
# the bespoke RotationStats that used to be defined here); the pipeline
# keeps incrementing ``self.stats.fwd`` / ``.inv`` at trace time and the
# analyzer audits the counts against the declared budget.


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

@dataclass(eq=False)
class ExchangePipeline:
    """Rotated-space quantized-exchange engine over a selectable backend."""
    bits: int = 8
    block: int = DEFAULT_BLOCK
    backend: str = "jnp"
    safety: float = 8.0

    def __post_init__(self):
        from repro.analysis.opbudget import OpBudget
        self.ops = get_backend(self.backend)
        self.stats = OpBudget()

    # -- helpers ------------------------------------------------------------
    def _pad(self, x2):
        d = x2.shape[-1]
        d_pad = pad_len(d, self.block)
        if d_pad == d:
            return x2.astype(jnp.float32)
        return jnp.pad(x2.astype(jnp.float32),
                       ((0, 0), (0, d_pad - d)))

    def signs_for(self, krot, d: int):
        return _signs(krot, pad_len(d, self.block))

    def _wire(self, wire: LatticeWire) -> LatticeWire:
        return wire if wire is not None else LatticeWire(self.bits)

    def gammas(self, dist_hints, xnorms, d: int, wire: LatticeWire = None):
        """Wrap-window γ from the distance hint, floored at the fp32
        precision limit of the message's own rotated coordinates (estimated
        pre-rotation from ‖x‖ so it fuses with the encode kernel)."""
        wire = self._wire(wire)
        base = wrap_gamma(dist_hints, d, bits=wire.bits, levels=wire.levels,
                          block=self.block, safety=self.safety)
        floor = coord_bound(xnorms, pad_len(d, self.block)) * GAMMA_NORM_FLOOR
        return jnp.maximum(base, floor)

    # -- counted primitive ops (inputs (m, d) original / (m, d_pad) rotated)
    def rotate(self, x2, signs):
        self.stats.fwd += int(x2.shape[0])
        return self.ops.rotate(self._pad(x2), signs, block=self.block)

    def rotate_encode(self, x2, signs, u2, gammas, *, want_rotated=True,
                      wire: LatticeWire = None):
        wire = self._wire(wire)
        self.stats.fwd += int(x2.shape[0])
        return self.ops.encode(self._pad(x2), signs, u2, gammas,
                               bits=wire.bits, block=self.block,
                               want_rotated=want_rotated, pack=wire.pack,
                               levels2=wire.levels)

    def quantize(self, y2_rot, u2, gammas, wire: LatticeWire = None):
        """Elementwise encode of ALREADY-ROTATED coords — no rotation pass
        (and no ``stats.fwd`` increment): stochastic round + wrap only."""
        wire = self._wire(wire)
        return self.ops.quantize(y2_rot, u2, gammas, bits=wire.bits,
                                 block=self.block, pack=wire.pack,
                                 levels2=wire.levels)

    def snap(self, codes2, wrot2, gammas, wire: LatticeWire = None):
        wire = self._wire(wire)
        return self.ops.snap(codes2, wrot2, gammas, bits=wire.bits,
                             block=self.block, pack=wire.pack,
                             levels2=wire.levels)

    def unrotate(self, y2, signs, d: int):
        self.stats.inv += int(y2.shape[0])
        return self.ops.rotate(y2, signs, block=self.block,
                               inverse=True)[:, :d]

    def decode(self, codes2, ref2, signs, gammas, d: int,
               wire: LatticeWire = None):
        """Full fused Dec(ref, msg): rotate ref + snap + inverse rotate."""
        wire = self._wire(wire)
        m = max(codes2.shape[0], ref2.shape[0])
        self.stats.fwd += int(ref2.shape[0])
        self.stats.inv += m
        return self.ops.decode(codes2, self._pad(ref2), signs, gammas,
                               bits=wire.bits, block=self.block,
                               pack=wire.pack, levels2=wire.levels)[:, :d]

    # -- per-round key/noise derivation (shared with the reference path) ----
    def _round_randomness(self, key, s: int, d: int):
        d_pad = pad_len(d, self.block)
        signs = self.signs_for(jax.random.fold_in(key, 0), d)
        u_srv = jax.random.uniform(jax.random.fold_in(key, 1), (1, d_pad),
                                   jnp.float32)
        k_cl = jax.random.split(jax.random.fold_in(key, 2), s)
        u_cl = jax.vmap(
            lambda k: jax.random.uniform(k, (d_pad,), jnp.float32))(k_cl)
        return signs, u_cl, u_srv

    # ------------------------------------------------------------------
    # one full QuAFL exchange, entirely in rotated coordinates
    # ------------------------------------------------------------------
    def quafl_round(self, key, server, Y, hints_up, *, avg_mode="both",
                    up: LatticeWire = None, down: LatticeWire = None):
        """Quantized exchange + (s+1)-averaging of one server round.

        server: (d,) X_t; Y: (s, d) client models at poll time; hints_up:
        (s,) upper estimates of ‖Y^i − X_t‖. ``up`` / ``down`` select the
        per-direction wire format (bit-width, sub-byte packing, optional
        per-message levels for heterogeneous client bit budgets); both
        default to this pipeline's uniform ``bits``. Returns (server_new
        (d,), clients_new (s, d), hint_srv, rel_err) — hint_srv is the
        downlink wrap hint (feeds ``srv_dist_est``), rel_err the mean
        relative quantization error of the uplink.
        """
        s, d = Y.shape
        up, down = self._wire(up), self._wire(down)
        signs, u_cl, u_srv = self._round_randomness(key, s, d)

        # uplink: fused rotate+encode of every client message; the rotated
        # coords come back for free and serve as downlink decode references.
        gam_up = self.gammas(hints_up, jnp.linalg.norm(Y, axis=1), d, up)
        Y_rot, codes_up = self.rotate_encode(Y, signs, u_cl, gam_up, wire=up)
        observe_lattice_wire(codes_up, gam_up, up, channel="up")
        srv_rot = self.rotate(server[None], signs)
        QY_rot = self.snap(codes_up, srv_rot, gam_up, up)      # (s, d_pad)

        # downlink: the server's γ depends on the decoded uplink, so its
        # encode cannot fold into the srv_rot pass above — but rot(X_t) is
        # already cached in ``srv_rot``, so Enc(X_t) is a pure elementwise
        # quantize of the cached coords (no second rotation pass; the round
        # budget is s+1 forward rotations, down from s+2).
        hint_srv = jnp.max(jnp.linalg.norm(QY_rot - srv_rot, axis=1)) + 1e-8
        gam_dn = self.gammas(hint_srv[None], jnp.linalg.norm(server)[None],
                             d, down)
        codes_dn = self.quantize(srv_rot, u_srv, gam_dn, down)
        observe_lattice_wire(codes_dn, gam_dn, down, channel="down")
        QX_rot = self.snap(codes_dn, Y_rot, gam_dn, down)      # (s, d_pad)

        # (s+1)-averaging in rotated coordinates; inverse-rotate only the
        # final states.
        if avg_mode in ("both", "server_only"):
            srv_new_rot = (srv_rot[0] + jnp.sum(QY_rot, 0)) / (s + 1)
        else:
            srv_new_rot = jnp.mean(QY_rot, 0)
        if avg_mode in ("both", "client_only"):
            cl_new_rot = QX_rot / (s + 1) + s * Y_rot / (s + 1)
        else:
            cl_new_rot = QX_rot
        server_new = self.unrotate(srv_new_rot[None], signs, d)[0]
        clients_new = self.unrotate(cl_new_rot, signs, d)

        rel_err = jnp.mean(jnp.linalg.norm(QY_rot - Y_rot, axis=1)
                           / (jnp.linalg.norm(Y_rot, axis=1) + 1e-9))
        return server_new, clients_new, hint_srv, rel_err

    # ------------------------------------------------------------------
    # equivalence oracle: per-message materialize-everything composition
    # ------------------------------------------------------------------
    def quafl_round_reference(self, key, server, Y, hints_up, *,
                              avg_mode="both", up: LatticeWire = None,
                              down: LatticeWire = None):
        """Same exchange over the same keys/noise/γ, composed message by
        message in original coordinates (the seed's structure). Used by the
        tests to pin the rotated-space path; O(s) extra rotation passes."""
        s, d = Y.shape
        up, down = self._wire(up), self._wire(down)
        signs, u_cl, u_srv = self._round_randomness(key, s, d)
        rot = partial(_rotate_jnp, block=self.block)
        unrot = partial(_rotate_jnp, block=self.block, inverse=True)

        gam_up = self.gammas(hints_up, jnp.linalg.norm(Y, axis=1), d, up)
        Yp = self._pad(Y)
        srvp = self._pad(server[None])
        codes_up = _encode_jnp(Yp, signs, u_cl, gam_up, bits=up.bits,
                               block=self.block, pack=up.pack,
                               levels2=up.levels)
        # each message decoded separately against the server (full rotate /
        # snap / inverse-rotate per message), back in original space
        QY = unrot(_snap_jnp(codes_up, rot(srvp, signs), gam_up,
                             bits=up.bits, block=self.block, pack=up.pack,
                             levels2=up.levels), signs)
        hint_srv = jnp.max(jnp.linalg.norm(QY - srvp, axis=1)) + 1e-8
        gam_dn = self.gammas(hint_srv[None], jnp.linalg.norm(server)[None],
                             d, down)
        codes_dn = _encode_jnp(srvp, signs, u_srv, gam_dn, bits=down.bits,
                               block=self.block, pack=down.pack,
                               levels2=down.levels)
        QX = unrot(_snap_jnp(codes_dn, rot(Yp, signs), gam_dn,
                             bits=down.bits, block=self.block,
                             pack=down.pack, levels2=down.levels), signs)

        if avg_mode in ("both", "server_only"):
            srv_new = (srvp[0] + jnp.sum(QY, 0)) / (s + 1)
        else:
            srv_new = jnp.mean(QY, 0)
        if avg_mode in ("both", "client_only"):
            cl_new = QX / (s + 1) + s * Yp / (s + 1)
        else:
            cl_new = QX
        rel_err = jnp.mean(jnp.linalg.norm(QY - Yp, axis=1)
                           / (jnp.linalg.norm(Yp, axis=1) + 1e-9))
        return srv_new[:d], cl_new[:, :d], hint_srv, rel_err
