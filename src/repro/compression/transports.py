"""Transport protocol: HOW the uplink aggregate moves over the mesh.

A codec decides what one message looks like; a transport decides how the
client-sum collective of the shard-local exchange
(:mod:`repro.core.exchange_local`) is carried over the interconnect. All
three strategies compute the SAME aggregate (they are pinned against each
other in ``tests/test_distributed.py``); they differ only in which bytes
cross the wire:

  ``shard_local``     decode/snap locally, all-reduce fp32 partial sums —
                      the faithful reading of Alg. 1 line 8 on a pod
                      (legacy name ``dequant_psum``)
  ``code_allgather``  all-gather the PACKED codec codes (uint8/16 — or the
                      sub-byte ``lattice_packed`` bytes, at b=4 HALF the
                      unpacked payload) + decode every message locally
  ``reduce_scatter``  NEW: snap locally in rotated space, ``psum_scatter``
                      the snapped chunks over the client axis, then
                      all-gather the reduced shards — the ROADMAP fusion
                      item: the reduce phase moves (n-1)/n · d words where
                      the fp32 all-reduce moves 2·(n-1)/n · d, halving the
                      uplink payload of the collective

Each transport exposes ``lattice_sum`` (rotated-space fused path) and
``generic_sum`` (per-message codec path). The registry mirrors
the codec/algorithm registries: select by name
(``FedConfig.transport = "shard_local_rs"`` maps here via
:func:`transport_for_mode`), extend via :func:`register_transport`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp


@runtime_checkable
class Transport(Protocol):
    """Structural type of a registered uplink-aggregation strategy."""

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        ...

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        ...


def _psum_maybe(x, axis, in_mesh):
    return jax.lax.psum(x, axis) if in_mesh else x


@dataclass(frozen=True)
class ShardLocalPsum:
    """fp32 all-reduce of locally decoded/snapped messages."""
    name: str = "shard_local"

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        return _psum_maybe(qy_own, client_axis, in_mesh)

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        return _psum_maybe(qy_own, client_axis, in_mesh)


@dataclass(frozen=True)
class CodeAllgather:
    """All-gather packed codes along the client axis; decode locally.

    Moves ``codec.message_bits`` per client over the interconnect instead
    of d fp32 words — with the ``lattice_packed`` codec the gathered bytes
    shrink by the packing factor too.
    """
    name: str = "code_allgather"

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        if not in_mesh:
            return qy_own
        codes_all = jax.lax.all_gather(codes[0].astype(code_dtype),
                                       client_axis)
        gam_all = jax.lax.all_gather(gammas[0], client_axis)
        return jnp.sum(pipe.snap(codes_all, srv_rot, gam_all, wire), 0,
                       keepdims=True)

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        if not in_mesh:
            return qy_own
        # gather every message leaf (codes, scales, indices, ...) so ANY
        # codec's wire format rides this transport
        msg_all = jax.tree_util.tree_map(
            lambda a: jax.lax.all_gather(a, client_axis), msg)
        qy_sum = jnp.zeros_like(srv)
        for j in range(n_slots):
            m_j = jax.tree_util.tree_map(lambda a: a[j], msg_all)
            qy_sum = qy_sum + quant.decode(key, m_j, srv)
        return qy_sum


@dataclass(frozen=True)
class ReduceScatterSum:
    """Reduce-scatter the snapped rotated chunks, then all-gather shards.

    ``psum = reduce_scatter + all_gather``; carrying the sum as an explicit
    reduce-scatter halves the payload of the reducing phase and leaves the
    summed shards in place for a future scattered downlink encode (ROADMAP:
    "fuse the uplink snap into the psum"). Falls back to the plain psum
    when the chunk length does not tile over the client axis.
    """
    name: str = "reduce_scatter"

    @staticmethod
    def _rs_ag(x, axis, n):
        d = x.shape[-1]
        if n <= 1 or d % n:
            return jax.lax.psum(x, axis)
        shard = jax.lax.psum_scatter(x, axis, scatter_dimension=x.ndim - 1,
                                     tiled=True)
        return jax.lax.all_gather(shard, axis, axis=x.ndim - 1, tiled=True)

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        if not in_mesh:
            return qy_own
        return self._rs_ag(qy_own, client_axis,
                           jax.lax.psum(1, client_axis))

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        if not in_mesh:
            return qy_own
        return self._rs_ag(qy_own, client_axis, n_slots)


_TRANSPORTS: Dict[str, object] = {
    "shard_local": ShardLocalPsum(),
    "code_allgather": CodeAllgather(),
    "reduce_scatter": ReduceScatterSum(),
}

# FedConfig.transport strings -> (runs the shard_map exchange?, registry
# name of the client-sum strategy). dequant_psum / code_allgather keep the
# legacy vmap composition in repro.launch.steps; the shard_local* family
# runs repro.core.exchange_local with the named strategy.
_MODE_MAP: Dict[str, str] = {
    "shard_local": "shard_local",
    "dequant_psum": "shard_local",
    "shard_local_codes": "code_allgather",
    "shard_local_rs": "reduce_scatter",
}


def registered_transports() -> Tuple[str, ...]:
    return tuple(_TRANSPORTS)


def register_transport(name: str, transport) -> None:
    if name in _TRANSPORTS:
        raise ValueError(f"transport {name!r} already registered")
    _TRANSPORTS[name] = transport


def make_transport(name: str):
    if name not in _TRANSPORTS:
        raise ValueError(f"unknown transport {name!r}; choose from "
                         f"{sorted(_TRANSPORTS)}")
    return _TRANSPORTS[name]


def transport_for_mode(fed_transport: str):
    """Map a ``FedConfig.transport`` string onto the shard-local exchange's
    client-sum strategy (``None`` = the transport is not a shard_map one)."""
    name = _MODE_MAP.get(fed_transport)
    return make_transport(name) if name is not None else None
