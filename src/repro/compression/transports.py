"""Transport protocol: HOW the uplink aggregate moves over the mesh.

A codec decides what one message looks like; a transport decides how the
client-sum collective of the shard-local exchange
(:mod:`repro.core.exchange_local`) is carried over the interconnect. All
three strategies compute the SAME aggregate (they are pinned against each
other in ``tests/test_distributed.py``); they differ only in which bytes
cross the wire:

  ``shard_local``     decode/snap locally, all-reduce fp32 partial sums —
                      the faithful reading of Alg. 1 line 8 on a pod
                      (legacy name ``dequant_psum``)
  ``code_allgather``  all-gather the PACKED codec codes (uint8/16 — or the
                      sub-byte ``lattice_packed`` bytes, at b=4 HALF the
                      unpacked payload) + decode every message locally
  ``reduce_scatter``  snap locally in rotated space, ``psum_scatter`` the
                      snapped chunks over the client axis, then move the
                      reduced shards back as a SCATTER-RESIDENT COMPRESSED
                      downlink: each device lattice-encodes its own reduced
                      shard and the all-gather carries packed integer codes
                      plus a γ-shards row instead of fp32 — the receiver
                      snaps the gathered codes against n·rot(X_t) post-
                      gather. The redistribution phase moves width/32 of
                      the fp32 re-gather bytes (b=4 packed: 1/8). The
                      aggregate is re-quantized at the downlink wire width
                      (the per-client lattices share no common grid, so an
                      exact coded re-gather is impossible); the error obeys
                      the same Lemma 3.1 wrap bound as the downlink encode
                      and the transport stays bit-identical across kernel
                      backends.

``shard_local`` and ``code_allgather`` compute the SAME aggregate (pinned
against each other in ``tests/test_distributed.py``); ``reduce_scatter``
agrees up to its γ_rs·√d̄ redistribution quantization, also pinned there.
Each transport exposes ``lattice_sum`` (rotated-space fused path) and
``generic_sum`` (per-message codec path); ``reduce_scatter`` additionally
exposes ``lattice_fused_sum`` (the scatter-resident coded path — the
shard-local exchange prefers it when present) and every transport reports
its gathered fp32 side-channel rows via ``extra_bits_down`` so the wire
accounting in :mod:`repro.launch.spmd` stays honest. The registry mirrors
the codec/algorithm registries: select by name
(``FedConfig.transport = "shard_local_rs"`` maps here via
:func:`transport_for_mode`), extend via :func:`register_transport`.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, NamedTuple, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp

from repro.analysis.provenance import wire_mark
from repro.kernels.exchange import block_geometry
from repro.compression.rotation import pad_len


class WireBudget(NamedTuple):
    """A transport's declared collective footprint for ONE exchanged leaf.

    ``caps`` upper-bounds every collective class the wire-truth audit
    meters (:func:`repro.analysis.jaxpr.collective_bytes` keys, bytes); a
    zero cap asserts the collective class is absent. ``float_reduce_ok``
    states whether model-sized fp32 payloads may enter reduce-class
    collectives (psum / psum_scatter) — the design of ``shard_local`` and
    ``reduce_scatter``, a wire leak on ``code_allgather``. These replace
    the hand-pinned byte caps the PR 9 ``rs_transport_audit`` carried.
    """
    caps: Dict[str, int]
    float_reduce_ok: bool


# scalar side traffic per exchanged leaf (hint/qerr psums): a loose upper
# bound, far below any model payload
_SCALAR_SLACK = 256


def _leaf_dpad(codec, d: int) -> int:
    """Padded length of one exchanged leaf: the shard-local exchange pads
    leaves to 1024 then the pipeline pads to its block geometry."""
    d1 = d + (-d) % 1024
    blk = getattr(codec, "block", None)
    return pad_len(d1) if blk is None else pad_len(d1, blk)


def _lattice_pair(codec_up, codec_down) -> bool:
    return (getattr(codec_up, "family", "") == "lattice"
            and getattr(codec_down, "family", "") == "lattice")


def _decl_gather_bytes(decl, n: int) -> Tuple[int, int]:
    """(int_bytes, float_bytes) an all-gather of one declared message
    costs per device (output = n stacked messages)."""
    ib = fb = 0
    for p in decl.parts:
        nbytes = n * p.elems * (p.container_bits // 8)
        if p.kind == "int":
            ib += nbytes
        else:
            fb += nbytes
    return ib, fb


@runtime_checkable
class Transport(Protocol):
    """Structural type of a registered uplink-aggregation strategy."""

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        ...

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        ...


def _psum_maybe(x, axis, in_mesh):
    return jax.lax.psum(x, axis) if in_mesh else x


def _shardable(d_pad: int, n: int, wire, block=None) -> bool:
    """Can a (1, d_pad) rotated vector be coded per reduce-scatter shard?
    Each shard must be its own valid block geometry (no repadding inside
    the collective) and, when the wire packs sub-byte, the shard's Hadamard
    sublane factor must still divide by ``pack``."""
    if n <= 1 or d_pad % n:
        return False
    d_sh = d_pad // n
    blk = {} if block is None else {"block": block}
    if pad_len(d_sh, **blk) != d_sh:
        return False
    _, _, r, _, _ = block_geometry(d_sh, **blk)
    return wire.pack == 1 or r % wire.pack == 0


def scatter_encode_gather(pipe, wire, vec_rot, ref_rot, gammas, key, n: int):
    """Single-host emulation of the scatter-resident coded redistribution.

    Splits the summed ROTATED vector (1, d_pad) into the ``n`` shards a
    ``psum_scatter`` leaves resident on each device, lattice-encodes every
    shard at the wire's width (what the all-gather would move), and snaps
    the gathered codes against the matching shards of ``ref_rot`` — the
    same kernel calls the distributed ``lattice_fused_sum`` makes, minus
    the collectives. Returns ``(decoded (1, d_pad), packed_codes
    (n, d_sh // pack))`` for benches and backend-equivalence tests.
    """
    d_pad = vec_rot.shape[-1]
    d_sh = d_pad // n
    shards = vec_rot.reshape(n, d_sh)
    gam_row = jnp.broadcast_to(jnp.asarray(gammas, jnp.float32).reshape(-1),
                               (n,))
    u = jax.random.uniform(key, shards.shape, jnp.float32)
    codes = pipe.quantize(shards, u, gam_row, wire)
    dec = pipe.snap(codes, ref_rot.reshape(n, d_sh), gam_row, wire)
    return dec.reshape(1, d_pad), codes


@dataclass(frozen=True)
class ShardLocalPsum:
    """fp32 all-reduce of locally decoded/snapped messages."""
    name: str = "shard_local"

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        return _psum_maybe(qy_own, client_axis, in_mesh)

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        return _psum_maybe(qy_own, client_axis, in_mesh)

    def extra_bits_down(self, codec_up, codec_down, d: int, n: int) -> int:
        """The psum reduction moves no extra redistribution payload."""
        return 0

    def wire_budget(self, codec_up, codec_down, d: int, n: int) -> WireBudget:
        """One fp32 all-reduce of the decoded partials; nothing gathered."""
        dp = _leaf_dpad(codec_up, d)
        return WireBudget(caps={
            "psum_fbytes": dp * 4 + _SCALAR_SLACK,
            "psum_ibytes": 0,
            "psum_scatter_fbytes": 0,
            "psum_scatter_ibytes": 0,
            "reduce_scatter_fbytes": 0,
            "reduce_scatter_ibytes": 0,
            "all_gather_fbytes": 0,
            "all_gather_ibytes": 0,
        }, float_reduce_ok=True)


@dataclass(frozen=True)
class CodeAllgather:
    """All-gather packed codes along the client axis; decode locally.

    Moves ``codec.message_bits`` per client over the interconnect instead
    of d fp32 words — with the ``lattice_packed`` codec the gathered bytes
    shrink by the packing factor too.
    """
    name: str = "code_allgather"

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        if not in_mesh:
            return qy_own
        # the gathered operands ARE the wire: marked in their container
        # form so the wire-truth audit can cross-check the collective
        d_leaf = int(codes.shape[-1]) * max(int(wire.pack), 1)
        codes_all = jax.lax.all_gather(
            wire_mark(codes[0].astype(code_dtype), channel="up",
                      part="codes", codec="wire", d=d_leaf), client_axis)
        gam_all = jax.lax.all_gather(
            wire_mark(gammas[0], channel="up", part="gamma", codec="wire",
                      d=d_leaf), client_axis)
        return jnp.sum(pipe.snap(codes_all, srv_rot, gam_all, wire), 0,
                       keepdims=True)

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        if not in_mesh:
            return qy_own
        # gather every message leaf (codes, scales, indices, ...) so ANY
        # codec's wire format rides this transport
        msg_all = jax.tree_util.tree_map(
            lambda a: jax.lax.all_gather(a, client_axis), msg)
        qy_sum = jnp.zeros_like(srv)
        for j in range(n_slots):
            m_j = jax.tree_util.tree_map(lambda a, j=j: a[j], msg_all)
            qy_sum = qy_sum + quant.decode(key, m_j, srv)
        return qy_sum

    def extra_bits_down(self, codec_up, codec_down, d: int, n: int) -> int:
        """The gathered per-client γ (and, for a grouped uplink, levels)
        f32 scalars are redistribution traffic: every device receives every
        other client's rows. ``message_bits`` already charges each client's
        OWN γ once (uplink); the other n-1 copies land here."""
        rows = 1
        wire = codec_up.wire() if hasattr(codec_up, "wire") else None
        if wire is not None and getattr(wire, "levels", None) is not None:
            rows += 1
        return rows * (n - 1) * 32

    def wire_budget(self, codec_up, codec_down, d: int, n: int) -> WireBudget:
        """Gathers exactly the declared uplink message (codes + side rows);
        reduce-class collectives carry scalars only."""
        decl = codec_up.wire_declaration(_leaf_dpad(codec_up, d))
        ib, fb = _decl_gather_bytes(decl, n)
        return WireBudget(caps={
            "psum_fbytes": _SCALAR_SLACK,
            "psum_ibytes": 0,
            "psum_scatter_fbytes": 0,
            "psum_scatter_ibytes": 0,
            "reduce_scatter_fbytes": 0,
            "reduce_scatter_ibytes": 0,
            "all_gather_fbytes": fb + _SCALAR_SLACK,
            "all_gather_ibytes": ib,
        }, float_reduce_ok=False)


@dataclass(frozen=True)
class ReduceScatterSum:
    """Reduce-scatter the snapped rotated chunks; coded shard re-gather.

    ``psum = reduce_scatter + all_gather``; carrying the sum as an explicit
    reduce-scatter halves the payload of the reducing phase AND leaves each
    device holding its reduced shard — so the redistribution is encoded
    scatter-resident: every device lattice-quantizes its OWN shard of the
    aggregate at the downlink wire width and the all-gather moves packed
    integer codes plus the (n,) γ-shards row instead of fp32. The receiver
    reassembles the gathered per-shard codes as an (n, d_sh) message batch
    and snaps them against the matching shards of the reference n·rot(X_t)
    — the Lemma 3.1 wrap bound holds with hint Σᵢ‖QYᵢ − rot(X_t)‖ by the
    triangle inequality. Falls back to the plain psum (exact, uncoded) when
    the chunk does not tile into valid per-shard block geometries
    (:func:`_shardable`) or outside the mesh.
    """
    name: str = "reduce_scatter"

    @staticmethod
    def _rs_ag(x, axis, n):
        d = x.shape[-1]
        if n <= 1 or d % n:
            return jax.lax.psum(x, axis)
        shard = jax.lax.psum_scatter(x, axis, scatter_dimension=x.ndim - 1,
                                     tiled=True)
        return jax.lax.all_gather(shard, axis, axis=x.ndim - 1, tiled=True)

    def lattice_sum(self, pipe, wire, codes, gammas, srv_rot, qy_own,
                    client_axis, in_mesh, code_dtype):
        if not in_mesh:
            return qy_own
        return self._rs_ag(qy_own, client_axis,
                           jax.lax.psum(1, client_axis))

    def lattice_fused_sum(self, pipe, wire, qy_own, srv_rot, gam_rs, key,
                          client_axis):
        """Scatter-resident compressed redistribution of the client sum.

        ``gam_rs`` is the (1,) redistribution scale (identical on every
        device — derived from psum'd hints); ``key`` seeds the per-device
        stochastic-rounding noise (decode never needs it). Returns the
        re-quantized (1, d_pad) rotated aggregate, bit-identical on every
        device (same gathered codes, same replicated reference).
        """
        n = jax.lax.psum(1, client_axis)
        d_pad = qy_own.shape[-1]
        if not _shardable(d_pad, n, wire, pipe.block):
            return jax.lax.psum(qy_own, client_axis)
        d_sh = d_pad // n
        shard = jax.lax.psum_scatter(qy_own, client_axis,
                                     scatter_dimension=qy_own.ndim - 1,
                                     tiled=True)            # (1, d_sh)
        u = jax.random.uniform(key, shard.shape, jnp.float32)
        codes_sh = pipe.quantize(shard, u, gam_rs, wire)    # (1, d_sh//pack)
        # the wire: packed integer codes + the γ-shards row, NOT fp32. The
        # gather moves the codes in their declared storage container (the
        # working uint32 of the unpacked path would quadruple the bytes);
        # snap consumes any uint container, as on the code_allgather path.
        cont = (jnp.uint8 if wire.pack > 1 or wire.bits <= 8 else
                (jnp.uint16 if wire.bits <= 16 else jnp.uint32))
        codes_all = jax.lax.all_gather(
            wire_mark(codes_sh[0].astype(cont), channel="down",
                      part="codes", codec="wire", d=d_sh), client_axis)
        gam_all = jax.lax.all_gather(
            wire_mark(gam_rs[0], channel="down", part="gamma",
                      codec="wire", d=d_sh), client_axis)   # (n,) f32
        ref_sh = (float(n) * srv_rot).reshape(n, d_sh)
        qy_hat = pipe.snap(codes_all, ref_sh, gam_all, wire)
        return qy_hat.reshape(1, d_pad)

    def generic_sum(self, quant, key, msg, srv, qy_own, client_axis,
                    in_mesh, n_slots):
        if not in_mesh:
            return qy_own
        return self._rs_ag(qy_own, client_axis, n_slots)

    def extra_bits_down(self, codec_up, codec_down, d: int, n: int) -> int:
        """The coded shard re-gather replaces the old (uncharged) fp32
        all-gather: every device receives one downlink-width code message
        plus the n-1 other γ shards — the codec's own wire math, moved into
        ``bits_down``."""
        if not hasattr(codec_down, "wire"):
            return 0   # generic codec pair: plain rs+ag of fp32 partials
        blk = getattr(codec_down, "block", None)
        d_pad = pad_len(d) if blk is None else pad_len(d, blk)
        if not _shardable(d_pad, n, codec_down.wire(), blk):
            return 0   # exact-psum fallback: reduction traffic only
        return codec_down.message_bits(d) + (n - 1) * 32

    def wire_budget(self, codec_up, codec_down, d: int, n: int) -> WireBudget:
        """Fused path: one psum_scatter of the fp32 partials + the coded
        shard re-gather at the downlink width. The tight psum cap asserts
        the fused path actually engaged (a silent fallback to plain psum
        is a byte-budget regression, not a numerics bug)."""
        dp = _leaf_dpad(codec_up, d)
        fused = (_lattice_pair(codec_up, codec_down)
                 and _shardable(dp, n, codec_down.wire(),
                                getattr(codec_down, "block", None)))
        if fused:
            decl = codec_down.wire_declaration(dp)
            codes = decl.part("codes")
            return WireBudget(caps={
                "psum_fbytes": _SCALAR_SLACK,
                "psum_ibytes": 0,
                # lax.psum_scatter lowers to the reduce_scatter
                # primitive; cap both names so neither leaks uncapped
                "psum_scatter_fbytes": dp * 4,
                "psum_scatter_ibytes": 0,
                "reduce_scatter_fbytes": dp * 4,
                "reduce_scatter_ibytes": 0,
                # gathered: every device ends with the full d_pad of codes
                # (n shards of d_sh) + the (n,) γ-shards row
                "all_gather_ibytes": codes.elems * (codes.container_bits
                                                    // 8),
                "all_gather_fbytes": n * 4 + _SCALAR_SLACK,
            }, float_reduce_ok=True)
        # generic pair / non-tiling geometry: rs+ag (or plain psum) of fp32
        return WireBudget(caps={
            "psum_fbytes": dp * 4 + _SCALAR_SLACK,
            "psum_ibytes": 0,
            "psum_scatter_fbytes": dp * 4,
            "psum_scatter_ibytes": 0,
            "reduce_scatter_fbytes": dp * 4,
            "reduce_scatter_ibytes": 0,
            "all_gather_fbytes": dp * 4 + _SCALAR_SLACK,
            "all_gather_ibytes": 0,
        }, float_reduce_ok=True)


_TRANSPORTS: Dict[str, object] = {
    "shard_local": ShardLocalPsum(),
    "code_allgather": CodeAllgather(),
    "reduce_scatter": ReduceScatterSum(),
}

# FedConfig.transport strings -> (runs the shard_map exchange?, registry
# name of the client-sum strategy). dequant_psum / code_allgather keep the
# legacy vmap composition in repro.launch.steps; the shard_local* family
# runs repro.core.exchange_local with the named strategy.
_MODE_MAP: Dict[str, str] = {
    "shard_local": "shard_local",
    "dequant_psum": "shard_local",
    "shard_local_codes": "code_allgather",
    "shard_local_rs": "reduce_scatter",
}


def registered_transports() -> Tuple[str, ...]:
    return tuple(_TRANSPORTS)


def register_transport(name: str, transport) -> None:
    if name in _TRANSPORTS:
        raise ValueError(f"transport {name!r} already registered")
    _TRANSPORTS[name] = transport


def make_transport(name: str):
    if name not in _TRANSPORTS:
        raise ValueError(f"unknown transport {name!r}; choose from "
                         f"{sorted(_TRANSPORTS)}")
    return _TRANSPORTS[name]


def transport_for_mode(fed_transport: str):
    """Map a ``FedConfig.transport`` string onto the shard-local exchange's
    client-sum strategy (``None`` = the transport is not a shard_map one)."""
    name = _MODE_MAP.get(fed_transport)
    return make_transport(name) if name is not None else None
