"""The paper's experimental classifier family (App. A.3): MLP for the
MNIST-style tasks. Used by the FL benchmark harness on the synthetic
Gaussian-mixture dataset (offline stand-in for MNIST/FMNIST/CIFAR/CelebA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import Ctx


def init_mlp_classifier(key, d_in: int, d_hidden: int, n_classes: int,
                        param_dtype: str = "float32"):
    ctx = Ctx(key, param_dtype)
    ctx.param("w1", (d_in, d_hidden), ("embed", "mlp"))
    ctx.param("b1", (d_hidden,), ("mlp",), init="zeros")
    ctx.param("w2", (d_hidden, n_classes), ("mlp", "vocab"))
    ctx.param("b2", (n_classes,), ("vocab",), init="zeros")
    return ctx.params, ctx.axes


def mlp_logits(params, x):
    h = jax.nn.relu(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, batch):
    """batch: {'x': (b, d), 'y': (b,) int}. Returns (loss, metrics)."""
    logits = mlp_logits(params, batch["x"]).astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, batch["y"][:, None], axis=-1)[:, 0]
    loss = jnp.mean(lse - tgt)
    acc = jnp.mean((jnp.argmax(logits, -1) == batch["y"]).astype(jnp.float32))
    return loss, {"acc": acc}
