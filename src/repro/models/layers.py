"""Shared building blocks: norms, RoPE, dense MLPs, embeddings."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x, weight=None, eps: float = 1e-6):
    """RMSNorm; weight=None gives the non-parametric form (OLMo-style)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * (1.0 + weight.astype(jnp.float32))
    return x.astype(dtype)


def init_norm(ctx, cfg, name: str, dim: int):
    if cfg.nonparametric_ln:
        return None
    ctx.param(f"{name}/scale", (dim,), (None,), init="zeros")


def apply_norm(cfg, p, name: str, x):
    if cfg.nonparametric_ln:
        return rms_norm(x, None)
    return rms_norm(x, p[f"{name}/scale"])


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., t, heads, head_dim); positions: (..., t) int32."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta))           # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs     # (..., t, half)
    cos = jnp.cos(ang)[..., None, :]                           # (..., t, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense (SwiGLU) MLP
# ---------------------------------------------------------------------------

def init_mlp(ctx, d_model: int, d_ff: int):
    ctx.param("w_gate", (d_model, d_ff), ("embed", "mlp"))
    ctx.param("w_up", (d_model, d_ff), ("embed", "mlp"))
    ctx.param("w_down", (d_ff, d_model), ("mlp", "embed"))


def apply_mlp(p, x, prefix: str = ""):
    pre = prefix + "/" if prefix else ""
    h = jax.nn.silu(x @ p[f"{pre}w_gate"].astype(x.dtype)) \
        * (x @ p[f"{pre}w_up"].astype(x.dtype))
    return h @ p[f"{pre}w_down"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embed(ctx, cfg):
    ctx.param("embed/tok", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
              scale=1.0 / np.sqrt(cfg.d_model))
    if not cfg.tie_embeddings:
        ctx.param("lm_head/w", (cfg.d_model, cfg.vocab_size),
                  ("embed", "vocab"))


def embed_tokens(cfg, p, tokens):
    x = jnp.take(p["embed/tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        # tied-head models (gemma) scale the embedding stream
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    return x


def lm_logits(cfg, p, x):
    if cfg.tie_embeddings:
        w = p["embed/tok"].astype(x.dtype)
        logits = x @ w.T
    else:
        logits = x @ p["lm_head/w"].astype(x.dtype)
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)
