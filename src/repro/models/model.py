"""Model assembly: layer blocks, scan-over-periods bodies, LM / enc-dec
forward passes (train, prefill, decode) and the LM loss.

Params and caches are FLAT dicts keyed by '/'-joined paths:
  embed/tok, lm_head/w, final_norm/scale,
  pre/{i}/<layer params>                      (unstacked prefix layers)
  body/{j}/<layer params>                     (leading 'layers' axis, scanned)
  enc/body/0/<layer params>                   (encoder stack, enc-dec models)
Caches mirror the layer paths.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import KIND_MAMBA, LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.layers import (apply_mlp, embed_tokens, init_embed,
                                 init_mlp, lm_logits, rms_norm)
from repro.models.params import Ctx, subtree

Constrain = Optional[Callable[[jax.Array], jax.Array]]


# ---------------------------------------------------------------------------
# per-layer init / axes
# ---------------------------------------------------------------------------

def _init_norm(ctx, cfg, name):
    if not cfg.nonparametric_ln:
        ctx.param(f"{name}/scale", (cfg.d_model,), (None,), init="zeros")


def _norm(cfg, p, name, x):
    w = None if cfg.nonparametric_ln else p[f"{name}/scale"]
    return rms_norm(x, w)


def init_layer(ctx, cfg: ModelConfig, spec: LayerSpec, cross: bool = False):
    _init_norm(ctx, cfg, "ln_seq")
    if spec.kind == KIND_MAMBA:
        mam.init_mamba(ctx.sub("mamba"), cfg)
    elif spec.attn == "mla":
        mla_mod.init_mla(ctx.sub("mla"), cfg)
    else:
        attn.init_attention(ctx.sub("attn"), cfg)
    if cross:
        _init_norm(ctx, cfg, "ln_cross")
        attn.init_attention(ctx.sub("cross"), cfg)
    if spec.mlp == "dense":
        _init_norm(ctx, cfg, "ln_mlp")
        init_mlp(ctx.sub("mlp"), cfg.d_model, cfg.d_ff)
    elif spec.mlp == "moe":
        _init_norm(ctx, cfg, "ln_mlp")
        moe_mod.init_moe(ctx.sub("moe"), cfg)


def _cross_attend(cfg, p, x, enc_k, enc_v):
    """Cross attention over precomputed encoder K/V (non-causal)."""
    b, t, _ = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["cross/wq"].astype(x.dtype)).reshape(b, t, h, dh)
    mask = jnp.ones((t, enc_k.shape[1]), dtype=bool)
    out = attn.sdpa(q, enc_k, enc_v, mask, 1.0 / np.sqrt(dh), 0.0)
    return out.reshape(b, t, -1) @ p["cross/wo"].astype(x.dtype)


def _cross_kv(cfg, p, enc_out):
    b, s, _ = enc_out.shape
    kv, dh = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["cross/wk"].astype(enc_out.dtype)).reshape(b, s, kv, dh)
    v = (enc_out @ p["cross/wv"].astype(enc_out.dtype)).reshape(b, s, kv, dh)
    return k, v


def apply_layer_prefill(cfg, spec, p, x, positions, cache=None,
                        write_pos=0, enc_out=None, constrain: Constrain = None):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, jax.Array] = {}
    h = _norm(cfg, p, "ln_seq", x)
    if spec.kind == KIND_MAMBA:
        lc = ({"conv": cache["mamba/conv"], "ssm": cache["mamba/ssm"]}
              if cache is not None else None)
        y, c = mam.mamba_prefill(cfg, p, h, prefix="mamba", cache=lc)
        if c is not None:
            new_cache["mamba/conv"], new_cache["mamba/ssm"] = c["conv"], c["ssm"]
    elif spec.attn == "mla":
        lc = ({"c_kv": cache["mla/c_kv"], "k_rope": cache["mla/k_rope"]}
              if cache is not None else None)
        y, c = mla_mod.mla_prefill(cfg, p, h, positions, prefix="mla",
                                   cache=lc, write_pos=write_pos)
        if c is not None:
            new_cache["mla/c_kv"], new_cache["mla/k_rope"] = c["c_kv"], c["k_rope"]
    else:
        lc = ({"k": cache["attn/k"], "v": cache["attn/v"]}
              if cache is not None else None)
        y, c = attn.attn_block_prefill(cfg, spec, p, h, positions,
                                       prefix="attn", cache=lc,
                                       write_pos=write_pos)
        if c is not None:
            new_cache["attn/k"], new_cache["attn/v"] = c["k"], c["v"]
    x = x + y
    if constrain:
        x = constrain(x)
    if enc_out is not None:
        ek, ev = _cross_kv(cfg, p, enc_out)
        x = x + _cross_attend(cfg, p, _norm(cfg, p, "ln_cross", x), ek, ev)
        if cache is not None:
            new_cache["cross/k"], new_cache["cross/v"] = ek, ev
    if spec.mlp == "dense":
        x = x + apply_mlp(p, _norm(cfg, p, "ln_mlp", x), prefix="mlp")
    elif spec.mlp == "moe":
        y, a = moe_mod.apply_moe(cfg, p, _norm(cfg, p, "ln_mlp", x),
                                 prefix="moe")
        x = x + y
        aux = aux + a
    if constrain:
        x = constrain(x)
    return x, new_cache, aux


def apply_layer_decode(cfg, spec, p, x, cur_pos, cache):
    """Single-token decode. Returns (x, new_cache)."""
    new_cache = dict(cache)
    h = _norm(cfg, p, "ln_seq", x)
    if spec.kind == KIND_MAMBA:
        y, c = mam.mamba_decode(
            cfg, p, h, {"conv": cache["mamba/conv"], "ssm": cache["mamba/ssm"]},
            prefix="mamba")
        new_cache["mamba/conv"], new_cache["mamba/ssm"] = c["conv"], c["ssm"]
    elif spec.attn == "mla":
        y, c = mla_mod.mla_decode(
            cfg, p, h, cur_pos,
            {"c_kv": cache["mla/c_kv"], "k_rope": cache["mla/k_rope"]},
            prefix="mla")
        new_cache["mla/c_kv"], new_cache["mla/k_rope"] = c["c_kv"], c["k_rope"]
    else:
        y, c = attn.attn_block_decode(
            cfg, spec, p, h, cur_pos,
            {"k": cache["attn/k"], "v": cache["attn/v"]}, prefix="attn")
        new_cache["attn/k"], new_cache["attn/v"] = c["k"], c["v"]
    x = x + y
    if "cross/k" in cache:
        x = x + _cross_attend(cfg, p, _norm(cfg, p, "ln_cross", x),
                              cache["cross/k"], cache["cross/v"])
    if spec.mlp == "dense":
        x = x + apply_mlp(p, _norm(cfg, p, "ln_mlp", x), prefix="mlp")
    elif spec.mlp == "moe":
        y, _ = moe_mod.apply_moe(cfg, p, _norm(cfg, p, "ln_mlp", x),
                                 prefix="moe")
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# whole-model init
# ---------------------------------------------------------------------------

def build_params(cfg: ModelConfig, key=None, abstract: bool = False):
    """Returns (params flat dict, axes flat dict)."""
    ctx = Ctx(key, cfg.param_dtype, abstract=abstract)
    root = ctx.sub("")
    init_embed(root, cfg)
    if cfg.encdec:
        enc_spec = LayerSpec()  # full-attn dense encoder layer
        init_layer(root.stacked("enc/body/0", cfg.n_enc_layers), cfg, enc_spec)
        _init_norm(root.sub("enc"), cfg, "final_norm")
    for i, spec in enumerate(cfg.prefix):
        init_layer(root.sub(f"pre/{i}"), cfg, spec, cross=cfg.encdec)
    for j, spec in enumerate(cfg.schedule):
        init_layer(root.stacked(f"body/{j}", cfg.n_periods), cfg, spec,
                   cross=cfg.encdec)
    _init_norm(root, cfg, "final_norm")
    return ctx.params, ctx.axes


def init_lm(cfg: ModelConfig, key):
    return build_params(cfg, key=key, abstract=False)


def abstract_lm(cfg: ModelConfig):
    return build_params(cfg, key=None, abstract=True)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg, spec, batch, max_seq, abstract, cross: bool,
                 enc_len: int):
    c: Dict[str, jax.Array] = {}
    if spec.kind == KIND_MAMBA:
        for k, v in mam.init_mamba_cache(cfg, batch, abstract).items():
            c[f"mamba/{k}"] = v
    elif spec.attn == "mla":
        for k, v in mla_mod.init_mla_cache(cfg, batch, max_seq,
                                           abstract).items():
            c[f"mla/{k}"] = v
    else:
        for k, v in attn.init_attn_cache(cfg, spec, batch, max_seq,
                                         abstract).items():
            c[f"attn/{k}"] = v
    if cross:
        shape = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        dt = jnp.dtype(cfg.dtype)
        for k in ("cross/k", "cross/v"):
            c[k] = (jax.ShapeDtypeStruct(shape, dt) if abstract
                    else jnp.zeros(shape, dt))
    return c


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False, enc_len: int = 0):
    """Flat cache dict mirroring layer paths. Stacked for the body."""
    cache: Dict[str, jax.Array] = {}
    for i, spec in enumerate(cfg.prefix):
        for k, v in _layer_cache(cfg, spec, batch, max_seq, abstract,
                                 cfg.encdec, enc_len).items():
            cache[f"pre/{i}/{k}"] = v
    n = cfg.n_periods
    for j, spec in enumerate(cfg.schedule):
        for k, v in _layer_cache(cfg, spec, batch, max_seq, abstract,
                                 cfg.encdec, enc_len).items():
            shape = (n,) + tuple(v.shape)
            cache[f"body/{j}/{k}"] = (
                jax.ShapeDtypeStruct(shape, v.dtype) if abstract
                else jnp.zeros(shape, v.dtype))
    return cache


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _encode(cfg, params, frontend, constrain: Constrain = None):
    """Bidirectional encoder over stub frontend embeddings (b, t, d)."""
    x = frontend.astype(jnp.dtype(cfg.dtype))
    body = subtree(params, "enc/body/0")
    positions = jnp.arange(x.shape[1])

    def step(carry, p_slice):
        h = _norm(cfg, p_slice, "ln_seq", carry)
        b, t, _ = h.shape
        hh, dh = cfg.n_heads, cfg.head_dim
        q = (h @ p_slice["attn/wq"].astype(h.dtype)).reshape(b, t, hh, dh)
        k = (h @ p_slice["attn/wk"].astype(h.dtype)).reshape(
            b, t, cfg.n_kv_heads, dh)
        v = (h @ p_slice["attn/wv"].astype(h.dtype)).reshape(
            b, t, cfg.n_kv_heads, dh)
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        mask = jnp.ones((t, t), dtype=bool)
        o = attn.sdpa(q, k, v, mask, 1.0 / np.sqrt(dh), cfg.attn_softcap)
        y = o.reshape(b, t, -1) @ p_slice["attn/wo"].astype(h.dtype)
        out = carry + y
        out = out + apply_mlp(p_slice, _norm(cfg, p_slice, "ln_mlp", out),
                              prefix="mlp")
        if constrain:
            out = constrain(out)
        return out, None

    x, _ = jax.lax.scan(step, x, body)
    return _norm(cfg, subtree(params, "enc"), "final_norm", x)


def forward(cfg: ModelConfig, params, batch, *, cache=None, write_pos=0,
            remat: bool = False, constrain: Constrain = None):
    """Full-sequence forward (train / prefill).

    batch: {'tokens': (b, t_text)} plus 'frontend': (b, t_f, d) for vlm/audio.
    Returns (logits over text positions, new_cache, aux_loss).
    """
    tokens = batch["tokens"]
    x = embed_tokens(cfg, params, tokens)
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(cfg, params, batch["frontend"], constrain)
    elif cfg.frontend:
        fe = batch["frontend"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)     # early fusion: prepend
    if constrain:
        x = constrain(x)
    b, t, _ = x.shape
    positions = jnp.arange(t)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, jax.Array] = {}

    for i, spec in enumerate(cfg.prefix):
        lc = subtree(cache, f"pre/{i}") if cache is not None else None
        x, c, a = apply_layer_prefill(cfg, spec, subtree(params, f"pre/{i}"),
                                      x, positions, cache=lc,
                                      write_pos=write_pos, enc_out=enc_out,
                                      constrain=constrain)
        aux += a
        for k, v in c.items():
            new_cache[f"pre/{i}/{k}"] = v

    body_p = {j: subtree(params, f"body/{j}")
              for j in range(len(cfg.schedule))}
    body_c = ({j: subtree(cache, f"body/{j}")
               for j in range(len(cfg.schedule))} if cache is not None
              else None)

    def period(carry, xs):
        x, aux = carry
        p_sl = xs["p"]
        c_sl = xs.get("c")
        outs = {}
        for j, spec in enumerate(cfg.schedule):
            lc = c_sl[j] if c_sl is not None else None
            x, c, a = apply_layer_prefill(cfg, spec, p_sl[j], x, positions,
                                          cache=lc, write_pos=write_pos,
                                          enc_out=enc_out,
                                          constrain=constrain)
            aux += a
            if c:
                outs[j] = c
        return (x, aux), outs

    step_fn = jax.checkpoint(period) if remat else period
    xs = {"p": body_p}
    if body_c is not None:
        xs["c"] = body_c
    (x, aux), body_new = jax.lax.scan(step_fn, (x, aux), xs)
    if cache is not None:
        for j, sub in body_new.items():
            for k, v in sub.items():
                new_cache[f"body/{j}/{k}"] = v

    x = _norm(cfg, params, "final_norm", x)
    if cfg.frontend and not cfg.encdec:
        x = x[:, -tokens.shape[1]:]              # logits over text positions
    logits = lm_logits(cfg, params, x)
    return logits, (new_cache if cache is not None else None), aux


def decode_step(cfg: ModelConfig, params, token, cur_pos, cache):
    """One-token decode. token: (b, 1) int32; cur_pos: scalar int32 (absolute
    position of this token, i.e. tokens already in cache). Returns
    (logits (b, 1, V), new_cache)."""
    x = embed_tokens(cfg, params, token)
    new_cache: Dict[str, jax.Array] = {}
    for i, spec in enumerate(cfg.prefix):
        x, c = apply_layer_decode(cfg, spec, subtree(params, f"pre/{i}"), x,
                                  cur_pos, subtree(cache, f"pre/{i}"))
        for k, v in c.items():
            new_cache[f"pre/{i}/{k}"] = v

    body_p = {j: subtree(params, f"body/{j}")
              for j in range(len(cfg.schedule))}
    body_c = {j: subtree(cache, f"body/{j}")
              for j in range(len(cfg.schedule))}

    def period(x, xs):
        outs = {}
        for j, spec in enumerate(cfg.schedule):
            x, c = apply_layer_decode(cfg, spec, xs["p"][j], x, cur_pos,
                                      xs["c"][j])
            outs[j] = c
        return x, outs

    x, body_new = jax.lax.scan(period, x, {"p": body_p, "c": body_c})
    for j, sub in body_new.items():
        for k, v in sub.items():
            new_cache[f"body/{j}/{k}"] = v
    x = _norm(cfg, params, "final_norm", x)
    return lm_logits(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch, *, remat: bool = False,
            constrain: Constrain = None):
    """Next-token cross-entropy (+ MoE aux). Returns (loss, metrics)."""
    logits, _, aux = forward(cfg, params, batch, remat=remat,
                             constrain=constrain)
    tokens = batch["tokens"]
    targets = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    ce = jnp.sum((lse - tgt) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux}
