"""GQA attention: full / sliding-window / chunked-local, prefill + decode.

Prefill is computed with a query-chunked ``lax.scan`` (flash-style tiling in
pure JAX) so the 32k shapes never materialize a full (t, t) score matrix and
the HLO stays compact. The Pallas flash-attention kernel in repro.kernels is
a drop-in replacement for the inner tile (TPU target; validated in interpret
mode).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN_CHUNKED, ATTN_SLIDING
from repro.models.layers import apply_rope, rms_norm, softcap

NEG_INF = -1e30

# §Perf switch: compute the QK contraction with bf16 partial sums. When the
# model axis over-splits head_dim (e.g. gemma2: 8 heads on a 16-way axis)
# GSPMD all-reduces score-matrix partials; emitting them in bf16 halves those
# bytes. Softmax still runs in fp32 after the (masked) upcast.
BF16_SCORE_PARTIALS = False

# Use the Pallas flash-attention kernel for prefill (full/sliding causal
# layers; chunked-local and non-tile-aligned shapes fall back to the jnp
# path). interpret=True on CPU; set False on real TPUs.
USE_FLASH_KERNEL = False
FLASH_INTERPRET = True


def _score_dtype(q):
    return q.dtype if BF16_SCORE_PARTIALS else jnp.float32


def init_attention(ctx, cfg):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ctx.param("wq", (d, h * dh), ("embed", "q_flat"))
    ctx.param("wk", (d, kv * dh), ("embed", "kv_flat"))
    ctx.param("wv", (d, kv * dh), ("embed", "kv_flat"))
    ctx.param("wo", (h * dh, d), ("q_flat", "embed"))
    if cfg.qk_norm:
        ctx.param("q_norm/scale", (dh,), (None,), init="zeros")
        ctx.param("k_norm/scale", (dh,), (None,), init="zeros")


def _qkv(cfg, p, x, positions, use_rope: bool, prefix: str = "",
         theta: float = 0.0):
    pre = prefix + "/" if prefix else ""
    b, t, _ = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p[f"{pre}wq"].astype(x.dtype)).reshape(b, t, h, dh)
    k = (x @ p[f"{pre}wk"].astype(x.dtype)).reshape(b, t, kv, dh)
    v = (x @ p[f"{pre}wv"].astype(x.dtype)).reshape(b, t, kv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p[f"{pre}q_norm/scale"])
        k = rms_norm(k, p[f"{pre}k_norm/scale"])
    if use_rope and positions is not None:
        th = theta or cfg.rope_theta
        q = apply_rope(q, positions, th)
        k = apply_rope(k, positions, th)
    return q, k, v


def sdpa(q, k, v, mask, scale: float, attn_cap: float = 0.0):
    """q: (b, tq, h, dh); k, v: (b, tk, kv, dh); mask: (b?, tq, tk) bool."""
    b, tq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, tq, kvh, g, dh)
    scores = jnp.einsum("btkgd,bskd->bkgts", qg, k,
                        preferred_element_type=_score_dtype(q)
                        ).astype(jnp.float32) * scale
    scores = softcap(scores, attn_cap)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, tq, h, dh).astype(q.dtype)


def _pick_chunk(t: int) -> int:
    for c in (2048, 1024, 512, 256, 128):
        if t % c == 0 and t > c:
            return c
    return t


def attention_prefill(cfg, spec, q, k, v):
    """Causal self-attention over a full sequence (train / prefill).

    Query-chunked scan; sliding windows slice the key band instead of
    scanning all keys (compute matches the window, not the sequence).
    """
    b, t, h, dh = q.shape
    scale = 1.0 / np.sqrt(dh)
    window = spec.window

    if (USE_FLASH_KERNEL and spec.attn != ATTN_CHUNKED
            and t % 128 == 0 and dh % 8 == 0):
        from repro.kernels.flash_attention import flash_attention
        return flash_attention(
            q, k, v, causal=True,
            window=window if spec.attn == ATTN_SLIDING else 0,
            softcap=cfg.attn_softcap, interpret=FLASH_INTERPRET)

    if spec.attn == ATTN_CHUNKED and window and t % window == 0 and t > window:
        # block-diagonal: reshape into (chunks, window) and attend per chunk
        nc = t // window
        qc = q.reshape(b * nc, window, h, dh)
        kc = k.reshape(b * nc, window, k.shape[2], dh)
        vc = v.reshape(b * nc, window, v.shape[2], dh)
        pos = jnp.arange(window)
        mask = pos[:, None] >= pos[None, :]
        out = sdpa(qc, kc, vc, mask, scale, cfg.attn_softcap)
        return out.reshape(b, t, h, dh)

    cq = _pick_chunk(t)
    if cq == t:
        pos = jnp.arange(t)
        mask = pos[:, None] >= pos[None, :]
        if spec.attn in (ATTN_SLIDING, ATTN_CHUNKED) and window:
            if spec.attn == ATTN_SLIDING:
                mask &= pos[None, :] > pos[:, None] - window
            else:  # chunked, non-divisible small case
                mask &= (pos[:, None] // window) == (pos[None, :] // window)
        return sdpa(q, k, v, mask, scale, cfg.attn_softcap)

    nchunks = t // cq
    if spec.attn == ATTN_SLIDING and window:
        # pad keys in front by ceil(window/cq)*cq so each query chunk sees a
        # static band [c0 - band + cq, c0 + cq)
        band = int(np.ceil(window / cq)) * cq + cq
        pad = band - cq
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

        def step(_, idx):
            c0 = idx * cq
            qs = jax.lax.dynamic_slice_in_dim(q, c0, cq, axis=1)
            ks = jax.lax.dynamic_slice_in_dim(kp, c0, band, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, c0, band, axis=1)
            qpos = c0 + jnp.arange(cq)
            kpos = c0 - pad + jnp.arange(band)
            mask = ((qpos[:, None] >= kpos[None, :])
                    & (kpos[None, :] > qpos[:, None] - window)
                    & (kpos[None, :] >= 0))
            return None, sdpa(qs, ks, vs, mask, scale, cfg.attn_softcap)

        _, outs = jax.lax.scan(step, None, jnp.arange(nchunks))
        return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, dh)

    def step(_, idx):
        c0 = idx * cq
        qs = jax.lax.dynamic_slice_in_dim(q, c0, cq, axis=1)
        qpos = c0 + jnp.arange(cq)
        kpos = jnp.arange(t)
        mask = qpos[:, None] >= kpos[None, :]
        return None, sdpa(qs, k, v, mask, scale, cfg.attn_softcap)

    _, outs = jax.lax.scan(step, None, jnp.arange(nchunks))
    return jnp.moveaxis(outs, 0, 1).reshape(b, t, h, dh)


# ---------------------------------------------------------------------------
# decode (single token, KV cache; ring buffer for windowed layers)
# ---------------------------------------------------------------------------

def cache_len(spec, max_seq: int) -> int:
    """Ring-buffer length for a layer's cache."""
    if spec.attn in (ATTN_SLIDING, ATTN_CHUNKED) and spec.window:
        return min(spec.window, max_seq)
    return max_seq


def init_attn_cache(cfg, spec, batch: int, max_seq: int, abstract: bool):
    s = cache_len(spec, max_seq)
    kvd = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    dt = jnp.dtype(cfg.dtype)
    if abstract:
        return {"k": jax.ShapeDtypeStruct(kvd, dt),
                "v": jax.ShapeDtypeStruct(kvd, dt)}
    return {"k": jnp.zeros(kvd, dt), "v": jnp.zeros(kvd, dt)}


def attn_cache_axes(spec):
    # kv_heads shards over 'model' when divisible; otherwise head_dim takes
    # it (128 % 16 == 0 for every assigned arch) — decode caches at
    # batch=128 x 32k otherwise exceed per-device HBM (see EXPERIMENTS §Perf).
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def write_attn_cache(cache, k_new, v_new, pos):
    """Write t_new tokens starting at absolute position ``pos`` (ring)."""
    s = cache["k"].shape[1]
    t_new = k_new.shape[1]
    if t_new >= s:
        # keep the last s positions, ring-aligned: token at absolute position
        # q must land in slot q mod s.
        start = pos + t_new - s  # absolute position of the first kept token
        return {"k": jnp.roll(k_new[:, -s:], start, axis=1),
                "v": jnp.roll(v_new[:, -s:], start, axis=1)}
    slot = jnp.mod(pos, s)
    # dynamic_update_slice with wrap-around: do it in (up to) two writes via
    # roll — roll cache so that slot becomes 0, write at 0, roll back.
    def wr(buf, new):
        buf = jnp.roll(buf, -slot, axis=1)
        buf = jax.lax.dynamic_update_slice_in_dim(buf, new, 0, axis=1)
        return jnp.roll(buf, slot, axis=1)
    return {"k": wr(cache["k"], k_new), "v": wr(cache["v"], v_new)}


def ring_positions(s: int, cur_pos):
    """Absolute position held by each ring slot once ``cur_pos`` tokens have
    been written. Slot j holds the largest q < cur_pos with q ≡ j (mod s);
    negative => never written."""
    j = jnp.arange(s)
    last = cur_pos - 1
    return last - jnp.mod(last - j, s)


def attention_decode(cfg, spec, q, cache, cur_pos):
    """q: (b, 1, h, dh); cache k/v: (b, s, kv, dh); cur_pos: scalar = number
    of tokens already in the cache (the query's absolute position)."""
    s = cache["k"].shape[1]
    scale = 1.0 / np.sqrt(cfg.head_dim)
    kv_pos = ring_positions(s, cur_pos + 1)  # includes the just-written token
    valid = (kv_pos >= 0) & (kv_pos <= cur_pos)
    if spec.attn == ATTN_SLIDING and spec.window:
        valid &= kv_pos > cur_pos - spec.window
    elif spec.attn == ATTN_CHUNKED and spec.window:
        valid &= (kv_pos // spec.window) == (cur_pos // spec.window)
    mask = valid[None, None, :]  # (1, tq=1, s)
    return sdpa(q, cache["k"], cache["v"], mask, scale, cfg.attn_softcap)


# ---------------------------------------------------------------------------
# full layer-level entry points
# ---------------------------------------------------------------------------

def attn_block_prefill(cfg, spec, p, x, positions, prefix: str = "",
                       cache=None, write_pos=0):
    """Returns (out, new_cache). positions: (t,) absolute positions."""
    pre = prefix + "/" if prefix else ""
    q, k, v = _qkv(cfg, p, x, positions, spec.use_rope, prefix,
                   theta=spec.rope_theta)
    out = attention_prefill(cfg, spec, q, k, v)
    new_cache = None
    if cache is not None:
        new_cache = write_attn_cache(cache, k, v, write_pos)
    b, t = x.shape[:2]
    out = out.reshape(b, t, -1) @ p[f"{pre}wo"].astype(x.dtype)
    return out, new_cache


def attn_block_decode(cfg, spec, p, x, cur_pos, cache, prefix: str = ""):
    """x: (b, 1, d). Writes the new token into the ring, then attends."""
    pre = prefix + "/" if prefix else ""
    positions = jnp.full((1,), cur_pos, dtype=jnp.int32)
    q, k, v = _qkv(cfg, p, x, positions, spec.use_rope, prefix,
                   theta=spec.rope_theta)
    cache = write_attn_cache(cache, k, v, cur_pos)
    out = attention_decode(cfg, spec, q, cache, cur_pos)
    b = x.shape[0]
    out = out.reshape(b, 1, -1) @ p[f"{pre}wo"].astype(x.dtype)
    return out, cache
