"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Prefill/train uses the chunked SSD dual form: quadratic attention-like
computation inside fixed-size chunks plus a ``lax.scan`` state recurrence
across chunks (TPU-friendly: the intra-chunk part is MXU matmuls; no
per-token sequential scan). Decode is the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


def _dims(cfg):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    nheads = d_inner // m.head_dim
    conv_dim = d_inner + 2 * m.ngroups * m.d_state
    return m, d_inner, nheads, conv_dim


def init_mamba(ctx, cfg):
    m, d_inner, nheads, conv_dim = _dims(cfg)
    d = cfg.d_model
    proj_out = 2 * d_inner + 2 * m.ngroups * m.d_state + nheads
    ctx.param("in_proj", (d, proj_out), ("embed", "mlp"))
    ctx.param("conv_w", (m.conv_width, conv_dim), (None, "mlp"), scale=0.5)
    ctx.param("conv_b", (conv_dim,), ("mlp",), init="zeros")
    ctx.param("A_log", (nheads,), (None,), init="a_log")
    ctx.param("D", (nheads,), (None,), init="ones")
    ctx.param("dt_bias", (nheads,), (None,), init="uniform_dt")
    ctx.param("norm/scale", (d_inner,), ("mlp",), init="zeros")
    ctx.param("out_proj", (d_inner, d), ("mlp", "embed"))


def _split_proj(cfg, zxbcdt):
    m, d_inner, nheads, _ = _dims(cfg)
    gs = m.ngroups * m.d_state
    z = zxbcdt[..., :d_inner]
    xs = zxbcdt[..., d_inner:2 * d_inner]
    B = zxbcdt[..., 2 * d_inner:2 * d_inner + gs]
    C = zxbcdt[..., 2 * d_inner + gs:2 * d_inner + 2 * gs]
    dt = zxbcdt[..., 2 * d_inner + 2 * gs:]
    return z, xs, B, C, dt


def _conv_causal(cfg, p, u, pre, conv_state=None):
    """Depthwise causal conv over (b, t, conv_dim). conv_state: (b, w-1, cd)
    holds the trailing inputs from the previous segment (decode)."""
    m = cfg.mamba
    w = m.conv_width
    if conv_state is None:
        up = jnp.pad(u, ((0, 0), (w - 1, 0), (0, 0)))
    else:
        up = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    cw = p[f"{pre}conv_w"].astype(u.dtype)
    out = sum(up[:, i:i + u.shape[1]] * cw[i] for i in range(w))
    out = jax.nn.silu(out + p[f"{pre}conv_b"].astype(u.dtype))
    new_state = up[:, -(w - 1):] if w > 1 else up[:, :0]
    return out, new_state


def _ssd_chunked(xh, dt, A, B, C, chunk: int, init_state=None):
    """SSD dual form.

    xh: (b, t, h, p); dt: (b, t, h) (post-softplus); A: (h,) negative;
    B, C: (b, t, g, n) with g dividing h. Returns (y (b,t,h,p), state).
    """
    b, t, h, pdim = xh.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    B = jnp.repeat(B, rep, axis=2)      # (b, t, h, n)
    C = jnp.repeat(C, rep, axis=2)
    L = min(chunk, t)
    pad = (-t) % L
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    tt = t + pad
    nc = tt // L
    f32 = jnp.float32
    xh_, dt_, B_, C_ = (a.reshape(b, nc, L, *a.shape[2:]).astype(f32)
                        for a in (xh, dt, B, C))
    da = dt_ * A.astype(f32)[None, None, None, :]            # (b,c,l,h)
    cs = jnp.cumsum(da, axis=2)                              # cumulative decay
    seg = cs[:, :, -1:, :]                                   # chunk total

    # intra-chunk (quadratic in L): scores[i,j] = C_i.B_j exp(cs_i - cs_j) dt_j
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # (b,c,i,j,h)
    iidx, jidx = jnp.arange(L)[:, None], jnp.arange(L)[None, :]
    causal = (iidx >= jidx)[None, None, :, :, None]
    cb = jnp.einsum("bcihn,bcjhn->bcijh", C_, B_)
    scores = cb * decay * causal * dt_[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xh_)

    # per-chunk terminal state: sum_j exp(seg - cs_j) dt_j B_j x_j
    sdec = jnp.exp(seg - cs)                                 # (b,c,l,h)
    states = jnp.einsum("bclh,bclhn,bclhp->bchpn",
                        sdec * dt_, B_, xh_)                 # (b,c,h,p,n)

    # inter-chunk recurrence over c
    segc = jnp.exp(seg[:, :, 0, :])                          # (b,c,h)

    def step(carry, inp):
        st, dec, prev = carry, inp["dec"], inp["st"]
        new = st * dec[:, :, None, None] + prev
        return new, st                                       # emit state BEFORE chunk

    if init_state is None:
        init = jnp.zeros((b, h, pdim, n), f32)
    else:
        init = init_state.astype(f32)
    xs = {"dec": jnp.moveaxis(segc, 1, 0), "st": jnp.moveaxis(states, 1, 0)}
    final_state, prev_states = jax.lax.scan(step, init, xs)
    prev_states = jnp.moveaxis(prev_states, 0, 1)            # (b,c,h,p,n)

    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                         C_, prev_states, jnp.exp(cs))
    y = (y_intra + y_inter).reshape(b, tt, h, pdim)[:, :t]
    return y.astype(xh.dtype), final_state


def mamba_prefill(cfg, p, x, prefix: str = "", cache=None):
    """x: (b, t, d) -> (out, new_cache)."""
    pre = prefix + "/" if prefix else ""
    m, d_inner, nheads, conv_dim = _dims(cfg)
    b, t, _ = x.shape
    zxbcdt = x @ p[f"{pre}in_proj"].astype(x.dtype)
    z, xs, B, C, dt = _split_proj(cfg, zxbcdt)
    u = jnp.concatenate([xs, B, C], axis=-1)
    u, conv_state = _conv_causal(cfg, p, u, pre)
    xs = u[..., :d_inner]
    B = u[..., d_inner:d_inner + m.ngroups * m.d_state]
    C = u[..., d_inner + m.ngroups * m.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p[f"{pre}dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p[f"{pre}A_log"].astype(jnp.float32))
    xh = xs.reshape(b, t, nheads, m.head_dim)
    Bg = B.reshape(b, t, m.ngroups, m.d_state)
    Cg = C.reshape(b, t, m.ngroups, m.d_state)
    init_state = cache["ssm"] if cache is not None else None
    y, state = _ssd_chunked(xh, dt, A, Bg, Cg, m.chunk, init_state)
    y = y + xh.astype(jnp.float32).astype(y.dtype) \
        * p[f"{pre}D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, t, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p[f"{pre}norm/scale"])
    out = y @ p[f"{pre}out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                     "ssm": state.astype(cache["ssm"].dtype)}
    return out, new_cache


def init_mamba_cache(cfg, batch: int, abstract: bool):
    m, d_inner, nheads, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    shapes = {"conv": ((batch, m.conv_width - 1, conv_dim), dt),
              "ssm": ((batch, nheads, m.head_dim, m.d_state), jnp.float32)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    return {k: jnp.zeros(s, d) for k, (s, d) in shapes.items()}


def mamba_cache_axes():
    return {"conv": ("batch", None, "mlp"),
            "ssm": ("batch", None, None, None)}


def mamba_decode(cfg, p, x, cache, prefix: str = ""):
    """Single-token recurrent step. x: (b, 1, d)."""
    pre = prefix + "/" if prefix else ""
    m, d_inner, nheads, conv_dim = _dims(cfg)
    b = x.shape[0]
    zxbcdt = x @ p[f"{pre}in_proj"].astype(x.dtype)
    z, xs, B, C, dt = _split_proj(cfg, zxbcdt)
    u = jnp.concatenate([xs, B, C], axis=-1)                 # (b, 1, cd)
    u, conv_state = _conv_causal(cfg, p, u, pre, cache["conv"])
    xs = u[..., :d_inner]
    B = u[..., d_inner:d_inner + m.ngroups * m.d_state]
    C = u[..., d_inner + m.ngroups * m.d_state:]
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p[f"{pre}dt_bias"].astype(jnp.float32))  # (b,1,h)
    A = -jnp.exp(p[f"{pre}A_log"].astype(jnp.float32))
    xh = xs.reshape(b, nheads, m.head_dim).astype(jnp.float32)
    Bg = jnp.repeat(B.reshape(b, m.ngroups, m.d_state),
                    nheads // m.ngroups, axis=1).astype(jnp.float32)
    Cg = jnp.repeat(C.reshape(b, m.ngroups, m.d_state),
                    nheads // m.ngroups, axis=1).astype(jnp.float32)
    dt1 = dt[:, 0]                                           # (b, h)
    da = jnp.exp(dt1 * A[None, :])                           # (b, h)
    state = cache["ssm"].astype(jnp.float32)
    state = (state * da[:, :, None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt1, xh, Bg))
    y = jnp.einsum("bhpn,bhn->bhp", state, Cg) \
        + xh * p[f"{pre}D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p[f"{pre}norm/scale"])
    out = y @ p[f"{pre}out_proj"].astype(x.dtype)
    new_cache = {"conv": conv_state.astype(cache["conv"].dtype),
                 "ssm": state.astype(cache["ssm"].dtype)}
    return out, new_cache
