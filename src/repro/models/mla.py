"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Prefill uses the expanded (naive) form with query chunking. Decode uses the
ABSORBED form: W_UK is folded into the query and W_UV into the output so the
per-step attention runs directly over the compressed (kv_lora + rope) cache —
this is the TPU-friendly formulation (naive decode would re-expand the whole
cache every step: ~60 TFLOP/token for the 236B config).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30


def init_mla(ctx, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ctx.param("wq_a", (d, m.q_lora_rank), ("embed", "lora"))
    ctx.param("q_norm/scale", (m.q_lora_rank,), (None,), init="zeros")
    ctx.param("wq_b", (m.q_lora_rank, h * qd), ("lora", "q_flat"))
    ctx.param("wkv_a", (d, m.kv_lora_rank + m.qk_rope_dim), ("embed", "lora"))
    ctx.param("kv_norm/scale", (m.kv_lora_rank,), (None,), init="zeros")
    ctx.param("wkv_b", (m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim)),
              ("lora", "q_flat"))
    ctx.param("wo", (h * m.v_head_dim, d), ("q_flat", "embed"))


def _project_q(cfg, p, x, positions, pre):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ql = rms_norm(x @ p[f"{pre}wq_a"].astype(x.dtype), p[f"{pre}q_norm/scale"])
    q = (ql @ p[f"{pre}wq_b"].astype(x.dtype)).reshape(b, t, h, qd)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(cfg, p, x, positions, pre):
    m = cfg.mla
    kv = x @ p[f"{pre}wkv_a"].astype(x.dtype)
    c_kv = rms_norm(kv[..., :m.kv_lora_rank], p[f"{pre}kv_norm/scale"])
    k_rope = kv[..., m.kv_lora_rank:]           # (b, t, rope_dim), head-shared
    k_rope = apply_rope(k_rope[..., None, :], positions,
                        cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def mla_prefill(cfg, p, x, positions, prefix: str = "", cache=None,
                write_pos=0):
    """Expanded-form causal MLA over the full sequence."""
    pre = prefix + "/" if prefix else ""
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(cfg, p, x, positions, pre)
    c_kv, k_rope = _project_kv_latent(cfg, p, x, positions, pre)
    wkv_b = p[f"{pre}wkv_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    k_nope = jnp.einsum("btk,khn->bthn", c_kv, wkv_b[..., :m.qk_nope_dim])
    v = jnp.einsum("btk,khv->bthv", c_kv, wkv_b[..., m.qk_nope_dim:])
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)

    cq = 1024 if (t % 1024 == 0 and t > 1024) else t
    if cq == t:
        pos = jnp.arange(t)
        mask = pos[:, None] >= pos[None, :]
        out = _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, mask, scale)
    else:
        def step(_, idx):
            c0 = idx * cq
            qn = jax.lax.dynamic_slice_in_dim(q_nope, c0, cq, axis=1)
            qr = jax.lax.dynamic_slice_in_dim(q_rope, c0, cq, axis=1)
            qpos = c0 + jnp.arange(cq)
            mask = qpos[:, None] >= jnp.arange(t)[None, :]
            return None, _mla_sdpa(qn, qr, k_nope, k_rope, v, mask, scale)
        _, outs = jax.lax.scan(step, None, jnp.arange(t // cq))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, t, h, m.v_head_dim)

    new_cache = None
    if cache is not None:
        s = cache["c_kv"].shape[1]
        if t >= s:
            new_cache = {"c_kv": c_kv[:, -s:], "k_rope": k_rope[:, -s:]}
        else:
            new_cache = {
                "c_kv": jax.lax.dynamic_update_slice_in_dim(
                    cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                    write_pos, axis=1),
                "k_rope": jax.lax.dynamic_update_slice_in_dim(
                    cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
                    write_pos, axis=1)}
    out = out.reshape(b, t, -1) @ p[f"{pre}wo"].astype(x.dtype)
    return out, new_cache


def _mla_sdpa(q_nope, q_rope, k_nope, k_rope, v, mask, scale):
    from repro.models.attention import _score_dtype
    sd = _score_dtype(q_nope)
    scores = (jnp.einsum("bthn,bshn->bhts", q_nope, k_nope,
                         preferred_element_type=sd).astype(jnp.float32)
              + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope,
                           preferred_element_type=sd).astype(jnp.float32)
              ) * scale
    scores = jnp.where(mask[None, None] if mask.ndim == 2 else mask[:, None],
                       scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhts,bshv->bthv", probs, v.astype(jnp.float32))
    return out.astype(q_nope.dtype)


def init_mla_cache(cfg, batch: int, max_seq: int, abstract: bool):
    m = cfg.mla
    dt = jnp.dtype(cfg.dtype)
    shapes = {"c_kv": (batch, max_seq, m.kv_lora_rank),
              "k_rope": (batch, max_seq, m.qk_rope_dim)}
    if abstract:
        return {k: jax.ShapeDtypeStruct(v, dt) for k, v in shapes.items()}
    return {k: jnp.zeros(v, dt) for k, v in shapes.items()}


def mla_cache_axes():
    return {"c_kv": ("batch", "kv_seq", "kv_lora"),
            "k_rope": ("batch", "kv_seq", None)}


def mla_decode(cfg, p, x, cur_pos, cache, prefix: str = ""):
    """Absorbed-form single-token decode over the compressed cache."""
    pre = prefix + "/" if prefix else ""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((1,), cur_pos, dtype=jnp.int32)
    q_nope, q_rope = _project_q(cfg, p, x, positions, pre)   # (b,1,h,*)
    c_new, r_new = _project_kv_latent(cfg, p, x, positions, pre)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), cur_pos, axis=1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], r_new.astype(cache["k_rope"].dtype), cur_pos,
            axis=1),
    }
    wkv_b = p[f"{pre}wkv_b"].astype(x.dtype).reshape(
        m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_dim]            # (kv_lora, h, nope)
    w_uv = wkv_b[..., m.qk_nope_dim:]            # (kv_lora, h, v)
    # absorb W_UK into the query: q_c (b,1,h,kv_lora)
    q_c = jnp.einsum("bthn,khn->bthk", q_nope, w_uk)
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    s = cache["c_kv"].shape[1]
    kv_pos = jnp.arange(s)
    mask = kv_pos <= cur_pos                     # (s,)
    scores = (jnp.einsum("bthk,bsk->bhts", q_c.astype(jnp.float32),
                         cache["c_kv"].astype(jnp.float32))
              + jnp.einsum("bthr,bsr->bhts", q_rope.astype(jnp.float32),
                           cache["k_rope"].astype(jnp.float32))) * scale
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out_c = jnp.einsum("bhts,bsk->bthk", probs,
                       cache["c_kv"].astype(jnp.float32))   # (b,1,h,kv_lora)
    out = jnp.einsum("bthk,khv->bthv", out_c.astype(x.dtype), w_uv)
    out = out.reshape(b, 1, -1) @ p[f"{pre}wo"].astype(x.dtype)
    return out, cache
