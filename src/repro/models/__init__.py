from repro.models.model import (abstract_lm, decode_step, forward, init_cache,
                                init_lm, lm_loss)  # noqa: F401
