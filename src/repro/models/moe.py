"""Mixture-of-Experts layer: top-k router + grouped matmul experts.

Two implementations:
  * 'ragged' — sort tokens by expert and run ``jax.lax.ragged_dot`` grouped
    matmuls (MegaBlocks-style; FLOPs scale with *active* experts only).
  * 'dense'  — capacity-based one-hot dispatch/combine einsums (GShard-style
    fallback; used if ragged_dot will not partition on some topology).

Experts are tensor-parallel on the expert-FFN dimension ('expert_mlp' →
'model' mesh axis) by default; an expert-parallel variant ('experts' →
'model', tokens all-to-all) is a §Perf hillclimb option in the launcher.
Shared experts (DeepSeek/Llama4) are plain dense MLPs added to the output.
The router aux load-balance loss is returned to the caller and added to each
client's local objective.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mlp, init_mlp


_MOE_MESH = None  # set by the launcher for the 'ragged_shmap' impl


def set_moe_mesh(mesh):
    """Launcher hook: mesh used by the shard_map MoE implementation."""
    global _MOE_MESH
    _MOE_MESH = mesh


def init_moe(ctx, cfg):
    m = cfg.moe
    d = cfg.d_model
    ctx.param("router", (d, m.n_experts), ("embed", "experts"), scale=0.02)
    ctx.param("w_gate", (m.n_experts, d, m.d_ff_expert),
              ("experts", "embed", "expert_mlp"))
    ctx.param("w_up", (m.n_experts, d, m.d_ff_expert),
              ("experts", "embed", "expert_mlp"))
    ctx.param("w_down", (m.n_experts, m.d_ff_expert, d),
              ("experts", "expert_mlp", "embed"))
    if m.n_shared:
        ff = m.d_ff_shared or m.d_ff_expert * m.n_shared
        init_mlp(ctx.sub("shared"), d, ff)


def _router(cfg, p, x, pre):
    """x: (T, d) -> (weights (T, k), idx (T, k), aux_loss)."""
    m = cfg.moe
    logits = (x @ p[f"{pre}router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    density = jnp.mean(probs, axis=0)                       # (E,)
    one_hot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)
    frac = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)       # (E,)
    aux = m.n_experts * jnp.sum(frac * density) * m.router_aux_coef
    return weights.astype(x.dtype), idx, aux


def _moe_ragged(cfg, p, x, weights, idx, pre):
    m = cfg.moe
    T, d = x.shape
    k = m.top_k
    flat_idx = idx.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_idx)
    inv = jnp.argsort(order)
    xs = jnp.repeat(x, k, axis=0)[order]                     # (T*k, d) sorted
    group_sizes = jnp.bincount(flat_idx, length=m.n_experts).astype(jnp.int32)
    h = (jax.nn.silu(jax.lax.ragged_dot(xs, p[f"{pre}w_gate"].astype(x.dtype),
                                        group_sizes))
         * jax.lax.ragged_dot(xs, p[f"{pre}w_up"].astype(x.dtype),
                              group_sizes))
    y = jax.lax.ragged_dot(h, p[f"{pre}w_down"].astype(x.dtype), group_sizes)
    y = y[inv].reshape(T, k, d)
    return jnp.sum(y * weights[..., None], axis=1)


def _moe_dense(cfg, p, x, weights, idx, pre):
    """Capacity-based dispatch/combine (GShard). Exact when capacity covers
    all routed tokens; tokens over capacity are dropped (standard)."""
    m = cfg.moe
    T, d = x.shape
    cap = max(1, int(m.capacity_factor * T * m.top_k / m.n_experts))
    one_hot = jax.nn.one_hot(idx, m.n_experts, dtype=jnp.float32)  # (T,k,E)
    pos = jnp.cumsum(one_hot, axis=0) * one_hot - 1.0              # slot ids
    keep = (pos < cap) & (one_hot > 0)
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = jnp.einsum("tke,tkec->tec", one_hot * keep, pos_oh)
    combine = jnp.einsum("tk,tke,tkec->tec", weights.astype(jnp.float32),
                         one_hot * keep, pos_oh)
    xe = jnp.einsum("td,tec->ecd", x.astype(jnp.float32), dispatch)
    xe = xe.astype(x.dtype)
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p[f"{pre}w_gate"]
                                .astype(x.dtype)))
         * jnp.einsum("ecd,edf->ecf", xe, p[f"{pre}w_up"].astype(x.dtype)))
    y = jnp.einsum("ecf,efd->ecd", h, p[f"{pre}w_down"].astype(x.dtype))
    out = jnp.einsum("ecd,tec->td", y.astype(jnp.float32), combine)
    return out.astype(x.dtype)


def _moe_ragged_shmap(cfg, p, x, weights, idx, pre):
    """§Perf: the ragged grouped-matmul under shard_map.

    GSPMD has no native partitioning for lax.ragged_dot and falls back to a
    dense-masked matmul that materializes a (T·k, E·d) operand — 20+ TB per
    layer for deepseek-v2 at prefill_32k. Under shard_map every device runs
    the LOCAL ragged_dot on its token shard (full experts, 1/16 of the
    expert-FFN dim) and the only collective left is the algorithmically
    required psum of the down-projection partial sums over 'model'."""
    from jax.sharding import PartitionSpec as P
    mesh = _MOE_MESH
    assert mesh is not None, "set_moe_mesh(mesh) before using ragged_shmap"

    def local(xl, wl, il, wg, wu, wd):
        yl = _moe_ragged(cfg, {f"{pre}w_gate": wg, f"{pre}w_up": wu,
                               f"{pre}w_down": wd}, xl, wl, il, pre)
        return jax.lax.psum(yl, "model")

    tok_spec = P("data", None) if mesh.shape.get("data", 1) > 1 else P()
    from repro.utils.compat import shard_map
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(tok_spec, P("data", None) if tok_spec != P() else P(),
                  P("data", None) if tok_spec != P() else P(),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)),
        out_specs=tok_spec, check_vma=False)
    return fn(x, weights, idx.astype(jnp.int32),
              p[f"{pre}w_gate"].astype(x.dtype),
              p[f"{pre}w_up"].astype(x.dtype),
              p[f"{pre}w_down"].astype(x.dtype))


def apply_moe(cfg, p, x, prefix: str = ""):
    """x: (b, t, d) -> (out, aux_loss)."""
    pre = prefix + "/" if prefix else ""
    m = cfg.moe
    b, t, d = x.shape
    xf = x.reshape(b * t, d)
    weights, idx, aux = _router(cfg, p, xf, pre)
    if m.impl == "ragged":
        out = _moe_ragged(cfg, p, xf, weights, idx, pre)
    elif m.impl == "ragged_shmap":
        out = _moe_ragged_shmap(cfg, p, xf, weights, idx, pre)
    else:
        out = _moe_dense(cfg, p, xf, weights, idx, pre)
    if m.n_shared:
        out = out + apply_mlp(p, xf, prefix=(prefix + "/shared" if prefix
                                             else "shared"))
    return out.reshape(b, t, d), aux
