"""Parameter construction with logical-axis metadata.

Every parameter is created through a ``Ctx`` so that we simultaneously get:
  * the concrete array (init mode),
  * a ``jax.ShapeDtypeStruct`` (abstract mode, for dry-runs — no allocation),
  * a parallel dict of logical-axis tuples used by repro.sharding.rules.

Params are a FLAT dict keyed by '/'-joined paths; scanned layer stacks carry a
leading 'layers' axis created by ``StackCtx`` so the whole body lowers as one
``lax.scan`` (keeps the HLO small for the 48–72 layer architectures).

Logical axes used across the model zoo:
  vocab, embed, q_flat (n_heads*head_dim), kv_flat, mlp, experts, expert_mlp,
  lora, conv_dim, heads, layers (scan stacking), clients (per-client replica
  stacking in QuAFL's distributed mode).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.tree import fold_in_str

Axes = Tuple[Optional[str], ...]


class Ctx:
    """Records (path -> array/spec) and (path -> logical axes)."""

    def __init__(self, key: Optional[jax.Array], param_dtype: str,
                 abstract: bool = False):
        self.key = key
        self.abstract = abstract
        self.param_dtype = jnp.dtype(param_dtype)
        self.params: Dict[str, jax.Array] = {}
        self.axes: Dict[str, Axes] = {}

    def _make(self, path: str, shape, axes, init, scale):
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), self.param_dtype)
        k = fold_in_str(self.key, path)
        if init == "zeros":
            return jnp.zeros(shape, self.param_dtype)
        if init == "ones":
            return jnp.ones(shape, self.param_dtype)
        if init == "normal":
            if scale is None:
                fan_in = shape[-2] if len(shape) > 1 else 1
                scale = 1.0 / np.sqrt(max(fan_in, 1))
            x = jax.random.normal(k, tuple(shape), jnp.float32) * scale
            return x.astype(self.param_dtype)
        if init == "uniform_dt":  # mamba dt_bias: softplus^-1(U(1e-3, 1e-1))
            u = jax.random.uniform(k, tuple(shape), jnp.float32,
                                   minval=1e-3, maxval=1e-1)
            return jnp.log(jnp.expm1(u)).astype(self.param_dtype)
        if init == "a_log":  # mamba A in [1, 16]
            u = jax.random.uniform(k, tuple(shape), jnp.float32,
                                   minval=1.0, maxval=16.0)
            return jnp.log(u).astype(self.param_dtype)
        raise ValueError(init)

    def param(self, path: str, shape: Tuple[int, ...], axes: Axes,
              init: str = "normal", scale: Optional[float] = None):
        assert len(shape) == len(axes), (path, shape, axes)
        assert path not in self.params, f"duplicate param {path}"
        self.axes[path] = tuple(axes)
        arr = self._make(path, shape, axes, init, scale)
        self.params[path] = arr
        return arr

    def sub(self, prefix: str) -> SubCtx:
        return SubCtx(self, prefix, stack=0)


class SubCtx:
    """Prefixes paths; optionally prepends a stacked 'layers' dim of size n."""

    def __init__(self, parent: Ctx, prefix: str, stack: int = 0):
        self._p = parent
        self._prefix = prefix
        self._stack = stack

    @property
    def abstract(self):
        return self._p.abstract

    def param(self, path, shape, axes, init="normal", scale=None):
        full = f"{self._prefix}/{path}" if self._prefix else path
        if self._stack:
            shape = (self._stack,) + tuple(shape)
            axes = ("layers",) + tuple(axes)
        assert len(shape) == len(axes), (full, shape, axes)
        assert full not in self._p.params, f"duplicate param {full}"
        self._p.axes[full] = tuple(axes)
        arr = self._p._make(full, shape, axes, init, scale)
        self._p.params[full] = arr
        return arr

    def sub(self, prefix: str) -> SubCtx:
        pre = f"{self._prefix}/{prefix}" if self._prefix else prefix
        return SubCtx(self._p, pre, stack=self._stack)

    def stacked(self, prefix: str, n: int) -> SubCtx:
        pre = f"{self._prefix}/{prefix}" if self._prefix else prefix
        assert self._stack == 0, "nested stacking unsupported"
        return SubCtx(self._p, pre, stack=n)


# ---------------------------------------------------------------------------
# flat-dict subtree helpers (params are {path: array})
# ---------------------------------------------------------------------------

def subtree(params: Dict[str, jax.Array], prefix: str) -> Dict[str, jax.Array]:
    pre = prefix + "/"
    return {k[len(pre):]: v for k, v in params.items() if k.startswith(pre)}


def has_subtree(params: Dict[str, jax.Array], prefix: str) -> bool:
    pre = prefix + "/"
    return any(k.startswith(pre) for k in params)
