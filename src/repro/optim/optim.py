"""Minimal pure-pytree optimizers (optax is not available offline).

API: opt.init(params) -> state; opt.update(grads, state, params) ->
(updates, state). Updates are SUBTRACTED: p <- p - lr * update_direction is
folded into the update (updates already include the lr)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]


def sgd(lr: float, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return jax.tree_util.tree_map(jnp.zeros_like, params)
        return ()

    def update(grads, state, params=None):
        if momentum:
            state = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g, state, grads)
            upd = jax.tree_util.tree_map(lambda m: lr * m, state)
        else:
            upd = jax.tree_util.tree_map(lambda g: lr * g, grads)
        return upd, state

    return Optimizer(init, update)


class AdamState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params):
        z = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return AdamState(mu=z, nu=jax.tree_util.tree_map(jnp.copy, z),
                         count=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        count = state.count + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
            state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        upd = jax.tree_util.tree_map(
            lambda m, v: lr * (m / c1) / (jnp.sqrt(v / c2) + eps), mu, nu)
        return upd, AdamState(mu=mu, nu=nu, count=count)

    return Optimizer(init, update)
