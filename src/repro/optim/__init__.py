from repro.optim.optim import adam, sgd, Optimizer  # noqa: F401
