"""Paper Lemma 3.8 / §3.2: communication-bit accounting. QuAFL sends
O(sT·(d·b)) bits vs FedAvg's 2sT·d·32 — report the measured ratio."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, run_fedavg, run_quafl


def main(rounds: int = 30):
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=8,
                    swt=10.0)
    rq = run_quafl(fed, rounds, eval_every=rounds)
    rf = run_fedavg(fed, rounds, eval_every=rounds)
    bq = rq["hist"][-1][4]
    bf = rf["hist"][-1][4]
    emit("bits_quafl", rq["us_per_round"], f"bits={bq:.4g}")
    emit("bits_fedavg", rf["us_per_round"], f"bits={bf:.4g}")
    emit("bits_ratio", 0.0,
         f"fedavg_over_quafl={bf/bq:.2f};expected~{2*32/((fed.s+1)/fed.s*8):.1f}")


if __name__ == "__main__":
    main()
