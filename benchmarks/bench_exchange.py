"""Rotated-space exchange baseline (BENCH_exchange.json rows).

Times one full QuAFL quantized exchange (``ExchangePipeline.quafl_round``)
over a >=1M-parameter model vector at s in {8, 32} sampled clients, for the
``jnp`` and ``pallas_interpret`` backends, and reports

  * us/round wall time (jitted, post-compile),
  * the audited rotation counts (s+2 forward / s+1 inverse; the seed
    composition spent ~5s+1 full-model passes),
  * analytic HBM bytes moved by the fused path vs the seed composition.

**Codec dimension** (``exchange_codec_*`` rows): the same exchange under
registry codecs — uniform 8-bit lattice, 4-bit unpacked (uint8 wire), and
4-bit ``lattice_packed`` (2 codes/byte, packed inside the fused encode
kernel) — with the codecs' WIRE accounting (``bits_up`` for s uplink
messages) in the derived column. The committed baseline pins the packing
claim: ``lattice_packed`` at b=4 carries ~2x fewer ``bits_up`` than the
unpacked 4-bit row.

CPU caveat (same as bench_kernels): interpret-mode Pallas timing is a
correctness-validation datapoint, NOT a TPU projection — the interpreter
executes the grid serially. The jnp rows are the regression-tracked
numbers; the derived column carries the analytic traffic model used by the
roofline."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.compression.codecs import make_codec
from repro.compression.pipeline import ExchangePipeline
from repro.compression.rotation import pad_len

D_FULL = 1 << 20          # 1,048,576 >= 1M parameters
BITS = 8
CODEC_SPECS = ("lattice", "lattice:bits=4", "lattice_packed:bits=4")


def _traffic_bytes(d_pad: int, s: int, fused: bool) -> int:
    """Analytic HBM traffic of one exchange round, fp32 words + b-bit codes.

    Fused path: every rotation pass reads + writes d_pad fp32 once; encodes
    write codes, snaps read codes + reference. Seed composition additionally
    materialized the rotated vector, the scaled intermediate and per-client
    reference rotations (~5s+1 passes)."""
    f32 = 4 * d_pad
    code = d_pad * BITS // 8
    if fused:
        rot_passes = (s + 2) + (s + 1)            # fwd + inv, fused I/O
        return rot_passes * 2 * f32 + (s + 1) * code * 2 + s * 2 * f32
    rot_passes = 5 * s + 1
    # each un-fused rotation also materializes its input/output, and each
    # encode/decode re-reads + re-writes the full vector
    return rot_passes * 2 * f32 + (s + 1) * (3 * f32 + 2 * code)


def bench_round(d: int, s: int, backend: str, reps: int):
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=BITS, backend=backend)
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    d_pad = pad_len(d)
    emit(f"exchange_d{d}_s{s}_{backend}", us,
         f"rot_fwd={pipe.stats.fwd};rot_inv={pipe.stats.inv};"
         f"bytes_fused={_traffic_bytes(d_pad, s, True):.3g};"
         f"bytes_seed={_traffic_bytes(d_pad, s, False):.3g}")


def bench_codec_round(d: int, s: int, spec: str, backend: str, reps: int):
    """One full exchange under a registry codec's wire format; the derived
    column carries the codec-computed uplink accounting."""
    codec = make_codec(spec, bits=BITS, backend=backend)
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=codec.bits, block=codec.block,
                            safety=codec.safety, backend=backend)
    wire = codec.wire()
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h,
                                                       up=wire, down=wire))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    bits_up = s * codec.message_bits(d)
    name = spec.replace(":", "_").replace("=", "")
    emit(f"exchange_codec_{name}_d{d}_s{s}_{backend}", us,
         f"bits_up={bits_up};bits_per_coord={codec.message_bits(d) / d:.3f};"
         f"pack={codec.pack}")


def main(quick: int = 0):
    d = (1 << 17) if quick else D_FULL
    for s in (8, 32):
        # interpret mode runs the grid serially: one rep is plenty and the
        # number is a validation datapoint, not a projection
        bench_round(d, s, "jnp", reps=3)
        bench_round(d, s, "pallas_interpret", reps=1)
    # codec dimension: wire formats over the same exchange (jnp rows are
    # the regression-tracked numbers; one packed pallas_interpret row
    # validates the in-kernel pack/unpack path)
    for spec in CODEC_SPECS:
        bench_codec_round(d, 8, spec, "jnp", reps=2)
    bench_codec_round(d, 8, "lattice_packed:bits=4", "pallas_interpret",
                      reps=1)


if __name__ == "__main__":
    main()
