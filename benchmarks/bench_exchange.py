"""Rotated-space exchange baseline (BENCH_exchange.json rows).

Times one full QuAFL quantized exchange (``ExchangePipeline.quafl_round``)
over a >=1M-parameter model vector at s in {8, 32} sampled clients, for the
``jnp`` and ``pallas_interpret`` backends, and reports

  * us/round wall time (jitted, post-compile),
  * the audited rotation counts (s+2 forward / s+1 inverse; the seed
    composition spent ~5s+1 full-model passes),
  * analytic HBM bytes moved by the fused path vs the seed composition.

CPU caveat (same as bench_kernels): interpret-mode Pallas timing is a
correctness-validation datapoint, NOT a TPU projection — the interpreter
executes the grid serially. The jnp rows are the regression-tracked
numbers; the derived column carries the analytic traffic model used by the
roofline."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.compression.pipeline import ExchangePipeline
from repro.compression.rotation import pad_len

D_FULL = 1 << 20          # 1,048,576 >= 1M parameters
BITS = 8


def _traffic_bytes(d_pad: int, s: int, fused: bool) -> int:
    """Analytic HBM traffic of one exchange round, fp32 words + b-bit codes.

    Fused path: every rotation pass reads + writes d_pad fp32 once; encodes
    write codes, snaps read codes + reference. Seed composition additionally
    materialized the rotated vector, the scaled intermediate and per-client
    reference rotations (~5s+1 passes)."""
    f32 = 4 * d_pad
    code = d_pad * BITS // 8
    if fused:
        rot_passes = (s + 2) + (s + 1)            # fwd + inv, fused I/O
        return rot_passes * 2 * f32 + (s + 1) * code * 2 + s * 2 * f32
    rot_passes = 5 * s + 1
    # each un-fused rotation also materializes its input/output, and each
    # encode/decode re-reads + re-writes the full vector
    return rot_passes * 2 * f32 + (s + 1) * (3 * f32 + 2 * code)


def bench_round(d: int, s: int, backend: str, reps: int):
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=BITS, backend=backend)
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    d_pad = pad_len(d)
    emit(f"exchange_d{d}_s{s}_{backend}", us,
         f"rot_fwd={pipe.stats.fwd};rot_inv={pipe.stats.inv};"
         f"bytes_fused={_traffic_bytes(d_pad, s, True):.3g};"
         f"bytes_seed={_traffic_bytes(d_pad, s, False):.3g}")


def main(quick: int = 0):
    d = (1 << 17) if quick else D_FULL
    for s in (8, 32):
        # interpret mode runs the grid serially: one rep is plenty and the
        # number is a validation datapoint, not a projection
        bench_round(d, s, "jnp", reps=3)
        bench_round(d, s, "pallas_interpret", reps=1)


if __name__ == "__main__":
    main()
