"""Rotated-space exchange baseline (BENCH_exchange.json rows).

Times one full QuAFL quantized exchange (``ExchangePipeline.quafl_round``)
over a >=1M-parameter model vector at s in {8, 32} sampled clients, for the
``jnp`` and ``pallas_interpret`` backends, and reports

  * us/round wall time (jitted, post-compile),
  * the audited rotation counts (s+2 forward / s+1 inverse; the seed
    composition spent ~5s+1 full-model passes),
  * analytic HBM bytes moved by the fused path vs the seed composition.

**Codec dimension** (``exchange_codec_*`` rows): the same exchange under
registry codecs — uniform 8-bit lattice, 4-bit unpacked (uint8 wire), and
4-bit ``lattice_packed`` (2 codes/byte, packed inside the fused encode
kernel) — with the codecs' WIRE accounting (``bits_up`` for s uplink
messages) in the derived column. The committed baseline pins the packing
claim: ``lattice_packed`` at b=4 carries ~2x fewer ``bits_up`` than the
unpacked 4-bit row.

**Grouped dimension** (``exchange_codec_grouped_*`` rows): the same batched
exchange with HETEROGENEOUS per-message wrap moduli — half the sampled
clients at b=4, half at b=8, one levels row riding the kernels — on both
backends (the levels-row operand is the Pallas-side tentpole of the fused
distributed exchange).

**Redistribution dimension** (``exchange_rs_fused_*`` rows): the
scatter-resident coded re-gather of the fused ``reduce_scatter`` transport
(:func:`repro.compression.transports.scatter_encode_gather` — the same
kernel calls the distributed path makes, minus the collectives). The
derived column carries the wire math: ``bytes_fused`` (packed codes + the
(n-1) extra γ shards) vs ``bytes_fp32`` (the fp32 re-gather it replaces) —
at b=4 packed the ratio pins ~b/32 = 1/8.

CPU caveat (same as bench_kernels): interpret-mode Pallas timing is a
correctness-validation datapoint, NOT a TPU projection — the interpreter
executes the grid serially, so interpret rows run at the REDUCED
``D_INTERP`` size (the record name encodes d; the full-size interpret rows
took ~10 min/call and measured only the interpreter loop). The jnp rows
are the regression-tracked numbers; the derived column carries the
analytic traffic model used by the roofline."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.compression.codecs import make_codec, resolve_codec
from repro.compression.pipeline import ExchangePipeline, LatticeWire
from repro.compression.rotation import pad_len
from repro.compression.transports import scatter_encode_gather
from repro.configs.base import FedConfig

D_FULL = 1 << 20          # 1,048,576 >= 1M parameters
D_INTERP = 1 << 15        # serial-interpreter rows: validation, not speed
BITS = 8
CODEC_SPECS = ("lattice", "lattice:bits=4", "lattice_packed:bits=4")


def _traffic_bytes(d_pad: int, s: int, fused: bool) -> int:
    """Analytic HBM traffic of one exchange round, fp32 words + b-bit codes.

    Fused path: every rotation pass reads + writes d_pad fp32 once; encodes
    write codes, snaps read codes + reference. Seed composition additionally
    materialized the rotated vector, the scaled intermediate and per-client
    reference rotations (~5s+1 passes)."""
    f32 = 4 * d_pad
    code = d_pad * BITS // 8
    if fused:
        rot_passes = (s + 2) + (s + 1)            # fwd + inv, fused I/O
        return rot_passes * 2 * f32 + (s + 1) * code * 2 + s * 2 * f32
    rot_passes = 5 * s + 1
    # each un-fused rotation also materializes its input/output, and each
    # encode/decode re-reads + re-writes the full vector
    return rot_passes * 2 * f32 + (s + 1) * (3 * f32 + 2 * code)


def bench_round(d: int, s: int, backend: str, reps: int):
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=BITS, backend=backend)
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    d_pad = pad_len(d)
    emit(f"exchange_d{d}_s{s}_{backend}", us,
         f"rot_fwd={pipe.stats.fwd};rot_inv={pipe.stats.inv};"
         f"bytes_fused={_traffic_bytes(d_pad, s, True):.3g};"
         f"bytes_seed={_traffic_bytes(d_pad, s, False):.3g}")


def bench_codec_round(d: int, s: int, spec: str, backend: str, reps: int):
    """One full exchange under a registry codec's wire format; the derived
    column carries the codec-computed uplink accounting."""
    codec = make_codec(spec, bits=BITS, backend=backend)
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=codec.bits, block=codec.block,
                            safety=codec.safety, backend=backend)
    wire = codec.wire()
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h,
                                                       up=wire, down=wire))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    bits_up = s * codec.message_bits(d)
    name = spec.replace(":", "_").replace("=", "")
    emit(f"exchange_codec_{name}_d{d}_s{s}_{backend}", us,
         f"bits_up={bits_up};bits_per_coord={codec.message_bits(d) / d:.3f};"
         f"pack={codec.pack}")


def bench_grouped_round(d: int, s: int, backend: str, reps: int):
    """Heterogeneous-moduli exchange: half the sampled clients at b=4, half
    at b=8 — ONE batched round, the mixed wrap moduli riding the kernels as
    the per-message levels row (no extra rotation passes)."""
    fed = FedConfig(n_clients=s, s=s, bits=BITS, kernel_backend=backend)
    g = resolve_codec({"fast": "lattice", "slow": "lattice_packed:bits=4"},
                      fed, direction="up",
                      slow_mask=np.arange(s) < s // 2)
    key = jax.random.PRNGKey(0)
    server = jax.random.normal(key, (d,))
    Y = server[None] + 0.05 * jax.random.normal(
        jax.random.fold_in(key, 1), (s, d))
    hints = jnp.linalg.norm(Y - server[None], axis=1) + 1e-8
    pipe = ExchangePipeline(bits=g.bits, block=g.block, safety=g.safety,
                            backend=backend)
    up = g.wire()
    down = LatticeWire(bits=BITS)
    fn = jax.jit(lambda k, srv, y, h: pipe.quafl_round(k, srv, y, h,
                                                       up=up, down=down))
    jax.block_until_ready(fn(key, server, Y, hints))      # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(key, server, Y, hints))
    us = (time.time() - t0) / reps * 1e6
    bits_up = int(sum(g.message_bits_per_client(d)))
    widths = "/".join(map(str, g.wire_width_per_client))
    emit(f"exchange_codec_grouped_fast8_slow4_d{d}_s{s}_{backend}", us,
         f"bits_up={bits_up};widths={widths}")


def bench_rs_fused(d: int, n: int, spec: str, backend: str, reps: int):
    """Scatter-resident coded redistribution of the fused reduce_scatter
    transport: encode each of the ``n`` reduced shards at the wire width,
    snap the gathered codes against the reference — the derived column is
    the wire math of the re-gather (packed codes + the n-1 extra γ shards)
    vs the fp32 all-gather it replaces."""
    codec = make_codec(spec, bits=BITS, backend=backend)
    pipe = ExchangePipeline(bits=codec.bits, block=codec.block,
                            safety=codec.safety, backend=backend)
    wire = codec.wire()
    key = jax.random.PRNGKey(0)
    d_pad = pad_len(d, codec.block)
    vec = jax.random.normal(key, (1, d_pad))
    ref = vec + 0.05 * jax.random.normal(jax.random.fold_in(key, 1),
                                         (1, d_pad))
    hint = jnp.linalg.norm(vec - ref) + 1e-8
    gam = pipe.gammas(hint[None], jnp.linalg.norm(vec)[None], d_pad, wire)
    fn = jax.jit(lambda v, r, g, k: scatter_encode_gather(
        pipe, wire, v, r, g, k, n))
    jax.block_until_ready(fn(vec, ref, gam, key))         # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(vec, ref, gam, key))
    us = (time.time() - t0) / reps * 1e6
    bytes_fp32 = d_pad * 4
    bytes_fused = (codec.message_bits(d_pad) + (n - 1) * 32) // 8
    name = spec.replace(":", "_").replace("=", "")
    emit(f"exchange_rs_fused_{name}_d{d}_n{n}_{backend}", us,
         f"bytes_fused={bytes_fused};bytes_fp32={bytes_fp32};"
         f"ratio={bytes_fused / bytes_fp32:.4f}")


def main(quick: int = 0):
    d = (1 << 17) if quick else D_FULL
    di = (1 << 14) if quick else D_INTERP
    for s in (8, 32):
        # interpret mode runs the grid serially: one rep at the reduced
        # size is plenty — a validation datapoint, not a projection
        bench_round(d, s, "jnp", reps=3)
        bench_round(di, s, "pallas_interpret", reps=1)
    # codec dimension: wire formats over the same exchange (jnp rows are
    # the regression-tracked numbers; one packed pallas_interpret row
    # validates the in-kernel pack/unpack path)
    for spec in CODEC_SPECS:
        bench_codec_round(d, 8, spec, "jnp", reps=2)
    bench_codec_round(di, 8, "lattice_packed:bits=4", "pallas_interpret",
                      reps=1)
    # grouped (heterogeneous moduli) rows: the levels-row kernels on both
    # backends
    bench_grouped_round(d, 8, "jnp", reps=2)
    bench_grouped_round(di, 8, "pallas_interpret", reps=1)
    # scatter-resident coded redistribution (fused reduce_scatter wire)
    bench_rs_fused(d, 8, "lattice_packed:bits=4", "jnp", reps=3)
    bench_rs_fused(d, 8, "lattice", "jnp", reps=3)
    bench_rs_fused(di, 8, "lattice_packed:bits=4", "pallas_interpret",
                   reps=1)


if __name__ == "__main__":
    main()
