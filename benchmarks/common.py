"""Shared benchmark harness: one function per paper figure/table.

Every benchmark prints ``name,us_per_call,derived`` CSV rows plus a
``# curve:`` block with the convergence data the paper's figure plots.
The classification task is the Gaussian-mixture stand-in for the paper's
MNIST/FMNIST/CIFAR/CelebA (offline container; see DESIGN.md §3).
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FedConfig
from repro.core import FedAvg, FedBuff, QuAFL, Sequential
from repro.data import make_federated_classification
from repro.data.synthetic import client_batch
from repro.models.mlp import init_mlp_classifier, mlp_loss

D_IN, N_CLASSES, HIDDEN = 32, 10, 64


def setup(fed: FedConfig, seed: int = 0, iid: bool = True):
    part, test = make_federated_classification(
        seed, fed.n_clients, samples_per_client=256, d=D_IN,
        n_classes=N_CLASSES, iid=iid)
    params0, _ = init_mlp_classifier(jax.random.PRNGKey(seed), D_IN, HIDDEN,
                                     N_CLASSES)
    return part, test, params0


def batch_fn(data, key):
    return client_batch(key, data, 32)


def run_quafl(fed: FedConfig, rounds: int, seed: int = 0, iid: bool = True,
              eval_every: int = 10, **kw) -> Dict:
    part, test, params0 = setup(fed, seed, iid)
    alg = QuAFL(fed=fed, loss_fn=mlp_loss, template=params0,
                batch_fn=batch_fn, **kw)
    st = alg.init(params0)
    key = jax.random.PRNGKey(seed + 1)
    hist = []
    t0 = time.time()
    for r in range(rounds):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
        if (r + 1) % eval_every == 0:
            loss, metr = mlp_loss(alg.eval_params(st), test)
            hist.append((r + 1, float(st.sim_time), float(loss),
                         float(metr["acc"]), float(st.bits_sent)))
    wall = time.time() - t0
    return {"alg": alg, "state": st, "hist": hist,
            "us_per_round": wall / max(rounds, 1) * 1e6}


def run_fedavg(fed: FedConfig, rounds: int, seed: int = 0, iid: bool = True,
               eval_every: int = 10) -> Dict:
    part, test, params0 = setup(fed, seed, iid)
    alg = FedAvg(fed=fed, loss_fn=mlp_loss, template=params0,
                 batch_fn=batch_fn)
    st = alg.init(params0)
    key = jax.random.PRNGKey(seed + 1)
    hist = []
    t0 = time.time()
    for r in range(rounds):
        key, sub = jax.random.split(key)
        st, _ = alg.round(st, part, sub)
        if (r + 1) % eval_every == 0:
            loss, metr = mlp_loss(alg.eval_params(st), test)
            hist.append((r + 1, float(st.sim_time), float(loss),
                         float(metr["acc"]), float(st.bits_sent)))
    wall = time.time() - t0
    return {"state": st, "hist": hist,
            "us_per_round": wall / max(rounds, 1) * 1e6}


# machine-readable record of every emit() — benchmarks.run dumps this to
# BENCH_exchange.json so later PRs have a perf trajectory to diff against
RECORDS: List[Dict] = []


def emit(name: str, us: float, derived: str):
    RECORDS.append({"name": name, "us_per_call": float(us),
                    "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def emit_curve(name: str, hist: List):
    print(f"# curve:{name} round,sim_time,loss,acc,bits")
    for row in hist:
        print("#   " + ",".join(f"{v:.4g}" for v in row))
