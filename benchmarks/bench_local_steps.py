"""Paper Fig. 7 / 17: impact of the max local steps K."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    for K in (2, 5, 10):
        fed = FedConfig(n_clients=16, s=4, local_steps=K, lr=0.3, bits=14,
                        swt=10.0)
        r = run_quafl(fed, rounds, eval_every=rounds // 6)
        emit(f"K{K}", r["us_per_round"],
             f"acc={r['hist'][-1][3]:.3f};loss={r['hist'][-1][2]:.3f}")
        emit_curve(f"K{K}", r["hist"])


if __name__ == "__main__":
    main()
