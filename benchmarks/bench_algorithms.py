"""Every registered server algorithm under ONE clock (the paper's §5 /
App. A comparison as a benchmark): the full registry runs through
``compare()`` at an equal simulated-wall-clock budget on the shared non-iid
classification task, and each algorithm's accuracy / bits / rounds land in
``BENCH_algorithms.json`` so future PRs can diff the whole family at once.
"""
import jax

from repro.configs.base import FedConfig
from repro.fed import compare, make_algorithm, registered_algorithms
from repro.models.mlp import mlp_loss
from benchmarks.common import batch_fn, emit, emit_curve, setup

# per-algorithm construction kwargs (everything else is protocol-uniform)
_KWARGS = {
    "fedbuff": {"buffer_size": 4, "server_lr": 0.7, "quantize": True},
}


def main(rounds: int = 100):
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    part, test, params0 = setup(fed, iid=False)
    budget = rounds * (fed.swt + fed.sit)   # QuAFL-rounds' worth of sim time

    algs = {name: make_algorithm(name, fed, loss_fn=mlp_loss,
                                 template=params0, batch_fn=batch_fn,
                                 **_KWARGS.get(name, {}))
            for name in registered_algorithms()}
    def eval_fn(p):
        loss, metr = mlp_loss(p, test)
        return {"loss": float(loss), "acc": float(metr["acc"])}

    traces = compare(algs, params0, part, jax.random.PRNGKey(7),
                     until_sim_time=budget,
                     eval_every=max(rounds // 6, 1), eval_fn=eval_fn)

    for name, tr in traces.items():
        f = tr.final
        emit(f"alg_{name}", tr.us_per_round,
             f"acc={f['acc']:.3f};loss={f['loss']:.3f};"
             f"sim_t={f['sim_time']:.0f};rounds={tr.rounds};"
             f"bits_up={f['bits_up_total']:.3g};"
             f"bits_down={f['bits_down_total']:.3g}")
        emit_curve(f"alg_{name}", [
            (r["round"], r["sim_time"], r["loss"], r["acc"],
             r["bits_up_total"] + r["bits_down_total"]) for r in tr.rows])


if __name__ == "__main__":
    main()
