"""Every registered server algorithm under ONE clock (the paper's §5 /
App. A comparison as a benchmark), in two sections:

  * **compare** — the registry runs through ``compare()`` at an equal
    simulated-wall-clock budget on the shared non-iid classification task;
    each algorithm's accuracy / bits / rounds land in
    ``BENCH_algorithms.json`` so future PRs can diff the whole family.
  * **engine** (``alg_scan_*`` rows) — eager loop vs scanned engine
    (``simulate(..., scan_chunk=K)``) ``us_per_round`` for every registry
    algorithm on a d=2^20 flat-model task at s=8 (the quantizer is 'none'
    so the numbers isolate per-round ENGINE overhead, not kernel cost; the
    mesh-backed ``spmd`` entry times its own reduced-LM task and reports
    its actual d). The scanned path must stay strictly faster — that IS the
    device-resident round engine's reason to exist.

``spmd`` needs an LM config + token pools, so the compare section skips it
(the engine section covers it).
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.fed import compare, make_algorithm, registered_algorithms, simulate
from repro.models.mlp import mlp_loss
from benchmarks.common import batch_fn, emit, emit_curve, setup

# per-algorithm construction kwargs (everything else is protocol-uniform)
_KWARGS = {
    "fedbuff": {"buffer_size": 4, "server_lr": 0.7, "quantize": True},
    "fedbuff_device": {"buffer_size": 4, "server_lr": 0.7, "quantize": True},
}


def _compare_section(rounds: int):
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    part, test, params0 = setup(fed, iid=False)
    budget = rounds * (fed.swt + fed.sit)   # QuAFL-rounds' worth of sim time

    algs = {name: make_algorithm(name, fed, loss_fn=mlp_loss,
                                 template=params0, batch_fn=batch_fn,
                                 **_KWARGS.get(name, {}))
            for name in registered_algorithms() if name != "spmd"}

    def eval_fn(p):
        loss, metr = mlp_loss(p, test)
        return {"loss": float(loss), "acc": float(metr["acc"])}

    traces = compare(algs, params0, part, jax.random.PRNGKey(7),
                     until_sim_time=budget,
                     eval_every=max(rounds // 6, 1), eval_fn=eval_fn)

    for name, tr in traces.items():
        f = tr.final
        emit(f"alg_{name}", tr.us_per_round,
             f"acc={f['acc']:.3f};loss={f['loss']:.3f};"
             f"sim_t={f['sim_time']:.0f};rounds={tr.rounds};"
             f"bits_up={f['bits_up_total']:.3g};"
             f"bits_down={f['bits_down_total']:.3g}")
        emit_curve(f"alg_{name}", [
            (r["round"], r["sim_time"], r["loss"], r["acc"],
             r["bits_up_total"] + r["bits_down_total"]) for r in tr.rows])


# ---------------------------------------------------------------------------
# engine section: eager vs scanned us_per_round per registry algorithm
# ---------------------------------------------------------------------------

def _flat_task(d: int, n_clients: int, key):
    """A d-dimensional flat-model task with O(d) gradients and tiny data:
    state/exchange work scales with d while the per-step compute stays
    negligible, so the timing isolates the round ENGINE."""
    params0 = {"w": 0.01 * jax.random.normal(key, (d,), jnp.float32)}
    data = {"c": jax.random.uniform(jax.random.fold_in(key, 1),
                                    (n_clients, 32), jnp.float32,
                                    0.5, 1.5)}

    def loss_fn(params, batch):
        w = params["w"]
        return 0.5 * jnp.mean(batch["c"]) * jnp.sum(w * w), {}

    def bf(client_data, k):
        idx = jax.random.randint(k, (8,), 0, 32)
        return {"c": client_data["c"][idx]}

    return params0, data, loss_fn, bf


def _timed_us(alg, params0, data, rounds, chunk):
    """us_per_round of the second (compiled) run."""
    for _ in range(2):
        tr = simulate(alg, params0, data, jax.random.PRNGKey(3),
                      rounds=rounds, eval_every=0, scan_chunk=chunk)
    return tr.us_per_round, tr.engine


def _engine_section(quick: bool):
    d = 2 ** 14 if quick else 2 ** 20
    rounds = 8 if quick else 40
    chunk = 4 if quick else 20
    fed = FedConfig(n_clients=16, s=8, local_steps=2, lr=0.01,
                    quantizer="none")
    k0 = jax.random.PRNGKey(0)
    params0, data, loss_fn, bf = _flat_task(d, fed.n_clients, k0)

    for name in registered_algorithms():
        if name == "spmd":
            _engine_spmd(quick)
            continue
        kw = dict(_KWARGS.get(name, {}))
        kw.pop("quantize", None)   # engine timing: no kernel cost
        alg = make_algorithm(name, fed, loss_fn=loss_fn, template=params0,
                             batch_fn=bf, **kw)
        # python fedbuff cannot scan: its device twin provides the scanned
        # column (same event simulation as a pure pytree program)
        scan_alg = alg
        note = ""
        if name == "fedbuff":
            scan_alg = make_algorithm("fedbuff_device", fed,
                                      loss_fn=loss_fn, template=params0,
                                      batch_fn=bf, **kw)
            note = ";scan_engine=fedbuff_device"
        eager_us, _ = _timed_us(alg, params0, data, rounds, 0)
        scan_us, engine = _timed_us(scan_alg, params0, data, rounds, chunk)
        emit(f"alg_scan_{name}", scan_us,
             f"eager_us={eager_us:.0f};scanned_us={scan_us:.0f};"
             f"speedup={eager_us / max(scan_us, 1e-9):.2f}x;"
             f"d={d};s={fed.s};rounds={rounds};chunk={chunk};"
             f"engine={engine}{note}")


def _engine_spmd(quick: bool):
    """The mesh path times its own (reduced-LM) task — it is the one
    registry algorithm whose model is a params pytree on a mesh, not a
    flat vector."""
    import numpy as np

    from repro.configs import get_reduced
    from repro.data.synthetic import federated_token_task
    from repro.models.model import init_lm

    cfg = get_reduced("llama3.2-1b")
    fed = FedConfig(n_clients=1, s=1, local_steps=2, lr=0.05, bits=8)
    params0, _ = init_lm(cfg, jax.random.PRNGKey(0))
    d = int(sum(np.prod(v.shape) for v in params0.values()))
    data, bf = federated_token_task(0, 1, 8, 2, 16, cfg.vocab_size)
    alg = make_algorithm("spmd", fed, loss_fn=None, template=params0,
                         batch_fn=bf, cfg=cfg, batch=2, seq=16)
    rounds = 3 if quick else 8
    eager_us, _ = _timed_us(alg, params0, data, rounds, 0)
    scan_us, engine = _timed_us(alg, params0, data, rounds, rounds)
    emit("alg_scan_spmd", scan_us,
         f"eager_us={eager_us:.0f};scanned_us={scan_us:.0f};"
         f"speedup={eager_us / max(scan_us, 1e-9):.2f}x;"
         f"d={d};s=1;rounds={rounds};chunk={rounds};engine={engine};"
         f"arch={cfg.name}")


def main(rounds: int = 100):
    _compare_section(rounds)
    _engine_section(quick=rounds < 50)


if __name__ == "__main__":
    main()
