"""Population-engine benchmarks: round cost vs population size N, and the
participation specs at an equal simulated-clock budget.

Two sections, both landing in ``BENCH_algorithms.json``:

  * **scale** (``alg_pop_n*`` rows) — the scanned engine's ``us_per_round``
    at N=10^3 / 10^4 / 10^5 clients with the cohort FIXED at s=8 on a flat
    d=256 task. The population store turns N into memory instead of
    per-round work (O(s·d) gather/scatter, Floyd's O(s^2) sampler above
    ``DENSE_SAMPLE_MAX``), so the column must stay FLAT — the
    ``perf_smoke`` gate in ``tests/test_population.py`` enforces 1.5x.
  * **participation** (``alg_pop_part_*`` rows) — uniform vs
    gamma_straggler vs cyclic availability on the shared non-iid
    classification task at an equal sim-time budget: same algorithm, same
    clock, only WHO answers the polls differs. Cyclic availability is the
    heterogeneity stressor (only one phase group reachable per window);
    the derived fields carry the accuracy each schedule reaches.
"""
import jax
import jax.numpy as jnp

from repro.configs.base import FedConfig
from repro.fed import make_algorithm, simulate
from repro.models.mlp import mlp_loss
from benchmarks.common import batch_fn, emit, emit_curve, setup


def _flat_alg(n_clients: int, d: int = 256):
    """O(d)-gradient flat-model task (see bench_algorithms._flat_task) with
    a SHARED tiny batch pool: per-step compute and data stay negligible at
    every N, so the timing isolates population-store round cost."""
    fed = FedConfig(n_clients=n_clients, s=8, local_steps=2, lr=0.01,
                    quantizer="none")
    key = jax.random.PRNGKey(0)
    params0 = {"w": 0.01 * jax.random.normal(key, (d,), jnp.float32)}
    data = {"c": jnp.ones((1, 4), jnp.float32)}

    def loss_fn(params, batch):
        w = params["w"]
        return 0.5 * jnp.mean(batch["c"]) * jnp.sum(w * w), {}

    def bf(client_data, k):
        return {"c": client_data["c"]}

    alg = make_algorithm("quafl", fed, loss_fn=loss_fn, template=params0,
                         batch_fn=bf)
    return alg, params0, data


def _scale_section(quick: bool):
    rounds = 10 if quick else 40
    chunk = 5 if quick else 10
    sizes = (1_000, 10_000) if quick else (1_000, 10_000, 100_000)
    base_us = None
    for n in sizes:
        alg, params0, data = _flat_alg(n)
        for _ in range(2):   # compile+warmup, then the timed run
            tr = simulate(alg, params0, data, jax.random.PRNGKey(3),
                          rounds=rounds, eval_every=0, scan_chunk=chunk)
        base_us = base_us or tr.us_per_round
        emit(f"alg_pop_n{n}", tr.us_per_round,
             f"n={n};s=8;d=256;rounds={rounds};chunk={chunk};"
             f"engine={tr.engine};"
             f"vs_n1000={tr.us_per_round / base_us:.2f}x")


def _participation_section(rounds: int):
    fed = FedConfig(n_clients=64, s=8, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    part, test, params0 = setup(fed, iid=False)
    budget = rounds * (fed.swt + fed.sit)

    def eval_fn(p):
        loss, metr = mlp_loss(p, test)
        return {"loss": float(loss), "acc": float(metr["acc"])}

    specs = {
        "uniform": "uniform",
        "gamma": "gamma_straggler:strength=2",
        "cyclic": "cyclic:period=8,phase_groups=4",
    }
    for label, spec in specs.items():
        alg = make_algorithm("quafl", fed, loss_fn=mlp_loss,
                             template=params0, batch_fn=batch_fn,
                             participation=spec)
        tr = simulate(alg, params0, part, jax.random.PRNGKey(7),
                      until_sim_time=budget,
                      eval_every=max(rounds // 6, 1), eval_fn=eval_fn)
        f = tr.final
        emit(f"alg_pop_part_{label}", tr.us_per_round,
             f"spec={spec};acc={f['acc']:.3f};loss={f['loss']:.3f};"
             f"sim_t={f['sim_time']:.0f};rounds={tr.rounds};"
             f"n={fed.n_clients};s={fed.s}")
        emit_curve(f"alg_pop_part_{label}", [
            (r["round"], r["sim_time"], r["loss"], r["acc"],
             r["bits_up_total"] + r["bits_down_total"]) for r in tr.rows])


def main(rounds: int = 100):
    _scale_section(quick=rounds < 50)
    _participation_section(rounds)


if __name__ == "__main__":
    main()
