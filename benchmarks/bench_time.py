"""Paper Fig. 3 / 11–15 / 21–22: convergence vs SIMULATED TIME — QuAFL
(unweighted + weighted) vs FedAvg vs the sequential baseline, 25% slow
clients. QuAFL's non-blocking rounds finish in swt+sit while FedAvg waits
for the slowest sampled client."""
import jax

from repro.configs.base import FedConfig
from repro.core import Sequential
from repro.models.mlp import mlp_loss
from benchmarks.common import (batch_fn, emit, emit_curve, run_fedavg,
                               run_quafl, setup)


def main(rounds: int = 120):
    # Paper Fig. 3 setting: CIFAR = fixed random split (IID), 25% slow
    # clients Exp(1/8); synchronous FedAvg rounds cost ~max-straggler
    # Gamma(K, λ) while QuAFL rounds cost swt+sit.
    fed = FedConfig(n_clients=20, s=5, local_steps=10, lr=0.4, bits=14,
                    swt=10.0, slow_frac=0.25, lam_slow=1.0 / 8)
    r = run_quafl(fed, rounds, iid=True, eval_every=rounds // 8)
    emit("time_quafl", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
    emit_curve("time_quafl", r["hist"])

    fedw = FedConfig(n_clients=20, s=5, local_steps=10, lr=0.4, bits=14,
                     swt=10.0, slow_frac=0.25, lam_slow=1.0 / 8,
                     weighted=True)
    r = run_quafl(fedw, rounds, iid=True, eval_every=rounds // 8)
    emit("time_quafl_weighted", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
    emit_curve("time_quafl_weighted", r["hist"])

    # FedAvg round ~ max-straggler time: compare at EQUAL simulated time
    r = run_fedavg(fed, max(rounds // 10, 2), iid=True,
                   eval_every=max(rounds // 40, 1))
    emit("time_fedavg", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
    emit_curve("time_fedavg", r["hist"])

    # severe-straggler variant: slow clients at Exp(1/32) — the asynchrony
    # advantage grows with straggler severity
    feds = FedConfig(n_clients=20, s=5, local_steps=10, lr=0.4, bits=14,
                     swt=10.0, slow_frac=0.25, lam_slow=1.0 / 32)
    r = run_quafl(feds, rounds // 2, iid=True, eval_every=rounds // 8)
    emit("time_quafl_severe", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
    emit_curve("time_quafl_severe", r["hist"])
    r = run_fedavg(feds, 3, iid=True, eval_every=1)
    emit("time_fedavg_severe", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
    emit_curve("time_fedavg_severe", r["hist"])

    part, test, params0 = setup(fed, iid=True)
    seq = Sequential(fed=fed, loss_fn=mlp_loss, template=params0,
                     batch_fn=batch_fn)
    st = seq.init(params0)
    key = jax.random.PRNGKey(3)
    hist = []
    for t in range(rounds * 2):
        key, sub = jax.random.split(key)
        st, _ = seq.round(st, part, sub)
        if (t + 1) % (rounds // 4) == 0:
            loss, metr = mlp_loss(seq.eval_params(st), test)
            hist.append((t + 1, float(st.sim_time), float(loss),
                         float(metr["acc"]), 0.0))
    emit("time_sequential", 0.0, f"acc={hist[-1][3]:.3f}")
    emit_curve("time_sequential", hist)


if __name__ == "__main__":
    main()
