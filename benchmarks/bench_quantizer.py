"""Paper Fig. 5 / 16: lattice (position-aware) vs QSGD quantization inside
QuAFL at the same bit width."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    for quant in ("lattice", "qsgd"):
        fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=8,
                        quantizer=quant, swt=10.0)
        r = run_quafl(fed, rounds, eval_every=rounds // 6)
        emit(f"quant_{quant}", r["us_per_round"],
             f"acc={r['hist'][-1][3]:.3f};loss={r['hist'][-1][2]:.3f}")
        emit_curve(f"quant_{quant}", r["hist"])


if __name__ == "__main__":
    main()
