"""Paper Fig. 4: averaging variants on non-iid data — server+client
averaging (the paper's choice) vs one-sided variants."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=14,
                    swt=10.0)
    for mode in ("both", "server_only", "client_only"):
        r = run_quafl(fed, rounds, iid=False, eval_every=rounds // 6,
                      avg_mode=mode)
        emit(f"avg_{mode}", r["us_per_round"],
             f"acc={r['hist'][-1][3]:.3f};loss={r['hist'][-1][2]:.3f}")
        emit_curve(f"avg_{mode}", r["hist"])


if __name__ == "__main__":
    main()
