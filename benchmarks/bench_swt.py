"""Paper Fig. 9 / 20: server waiting time swt — too-frequent polling hurts
per-round progress (clients accumulate fewer local steps)."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    for swt in (1.0, 5.0, 20.0):
        fed = FedConfig(n_clients=16, s=4, local_steps=10, lr=0.3, bits=14,
                        swt=swt)
        r = run_quafl(fed, rounds, eval_every=rounds // 6)
        emit(f"swt{swt:g}", r["us_per_round"],
             f"acc={r['hist'][-1][3]:.3f};loss={r['hist'][-1][2]:.3f}")
        emit_curve(f"swt{swt:g}", r["hist"])


if __name__ == "__main__":
    main()
