"""Paper Fig. 2 / 19: impact of quantization bits b (saturation above ~10)."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    for b in (6, 8, 10, 32):
        fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=b,
                        quantizer="none" if b == 32 else "lattice", swt=10.0)
        r = run_quafl(fed, rounds, eval_every=rounds // 6)
        final = r["hist"][-1]
        emit(f"bits_b{b}", r["us_per_round"],
             f"acc={final[3]:.3f};loss={final[2]:.3f};bits={final[4]:.3g}")
        emit_curve(f"bits_b{b}", r["hist"])


if __name__ == "__main__":
    main()
