"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp reference.

On CPU the interpret-mode timing is NOT a TPU projection — the derived
column therefore reports the analytic FLOP/byte counts used by the roofline
model, plus wall-time of the jnp reference path for regression tracking."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.rotation import _signs, pad_len, rotate
from repro.kernels.exchange import fused_rotate
from repro.kernels.ref import flash_attention_ref, hadamard_ref
from benchmarks.common import emit


def _time(f, *args, n=5):
    # exactly ONE warm-up call (the old version evaluated f twice while
    # dispatching on the result type, skewing cache state for tiny kernels)
    jax.block_until_ready(f(*args))
    t0 = time.time()
    for _ in range(n):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / n * 1e6


def main():
    key = jax.random.PRNGKey(0)
    # rotation over a 10M-param model vector
    d = 10_000_000
    x = jax.random.normal(key, (d,))
    rot = jax.jit(lambda v: rotate(v, key))
    us = _time(rot, x)
    flops = 2 * d * (128 + 128)  # two 128-matmuls per element block
    emit("rotate_10M", us, f"flops={flops:.3g};bytes={d*4*2:.3g}")

    # jnp reference vs Pallas-interpret on the same 1M vector (interpret
    # executes the grid serially on CPU — a validation datapoint, not a
    # TPU projection; see module docstring)
    d1 = 1 << 20
    x1 = jax.random.normal(key, (d1,))
    us = _time(jax.jit(lambda v: rotate(v, key)), x1, n=3)
    emit("rotate_1M_jnp", us, f"flops={2*d1*(128+128):.3g};bytes={d1*4*2:.3g}")
    signs = _signs(key, pad_len(d1))
    x1p = x1[None]
    us = _time(lambda v: fused_rotate(v, signs), x1p, n=1)
    emit("rotate_1M_pallas_interpret", us,
         f"flops={2*d1*(128+128):.3g};bytes={d1*4*2:.3g}")

    # flash attention tile at the prefill_32k working point (scaled down)
    b, t, h, kv, dh = 1, 2048, 8, 2, 128
    q = jax.random.normal(key, (b, t, h, dh), jnp.bfloat16)
    k = jax.random.normal(key, (b, t, kv, dh), jnp.bfloat16)
    v = jax.random.normal(key, (b, t, kv, dh), jnp.bfloat16)
    att = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    us = _time(att, q, k, v)
    emit("attention_ref_2k", us,
         f"flops={4*b*h*t*t*dh:.3g};bytes={(q.size+k.size+v.size)*2:.3g}")


if __name__ == "__main__":
    main()
