"""Benchmark harness entry point — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUBSTR]``
prints ``name,us_per_call,derived`` CSV (+ ``# curve:`` blocks carrying the
convergence data each paper figure plots) and writes every emitted row to
``BENCH_exchange.json`` (machine-readable per-benchmark us + derived
flops/bytes) so subsequent PRs have a perf trajectory to diff against.
``--only`` filters benchmarks by name substring (e.g. ``--only exchange``).
"""
import json
import os
import sys
import time

from benchmarks import (bench_averaging, bench_bits, bench_bits_accounting,
                        bench_exchange, bench_extensions, bench_fedbuff,
                        bench_kernels, bench_local_steps, bench_peers,
                        bench_quantizer, bench_roofline, bench_swt,
                        bench_time)
from benchmarks.common import RECORDS

BENCHES = [
    ("Fig1_peers", bench_peers.main),
    ("Fig2_bits", bench_bits.main),
    ("Fig3_time", bench_time.main),
    ("Fig4_averaging", bench_averaging.main),
    ("Fig5_quantizer", bench_quantizer.main),
    ("Fig6_fedbuff", bench_fedbuff.main),
    ("Fig7_local_steps", bench_local_steps.main),
    ("Fig9_swt", bench_swt.main),
    ("Lemma38_bits", bench_bits_accounting.main),
    ("ext_scaffold_adaptive", bench_extensions.main),
    ("kernels", bench_kernels.main),
    ("exchange", bench_exchange.main),
    ("roofline", bench_roofline.main),
]

JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_exchange.json")


def _arg_value(flag: str):
    if flag in sys.argv:
        i = sys.argv.index(flag)
        if i + 1 < len(sys.argv):
            return sys.argv[i + 1]
    return None


def main() -> None:
    quick = "--quick" in sys.argv
    only = _arg_value("--only")
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if only and only not in name:
            continue
        t0 = time.time()
        print(f"# === {name} ===")
        try:
            if fn.__code__.co_argcount and quick:
                fn(20)
            else:
                fn()
        except Exception as e:  # keep the harness going
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if not RECORDS:
        print(f"# no records emitted (bad --only filter?); "
              f"leaving {JSON_PATH} untouched")
        return
    # quick-scale numbers are not comparable with the committed baseline —
    # keep them in a sibling file so the perf trajectory stays clean
    path = JSON_PATH.replace(".json", ".quick.json") if quick else JSON_PATH
    # merge by name: a partial run (--only) refreshes its own rows without
    # clobbering the rest of the committed baseline
    merged = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                merged = {r["name"]: r for r in json.load(f).get("benches",
                                                                 [])}
        except (ValueError, KeyError):
            merged = {}
    merged.update({r["name"]: r for r in RECORDS})
    with open(path, "w") as f:
        json.dump({"schema": "bench.v1", "quick": quick,
                   "benches": list(merged.values())}, f, indent=2)
    print(f"# wrote {len(RECORDS)} records ({len(merged)} total) to {path}")


if __name__ == "__main__":
    main()
