"""Benchmark harness entry point — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick]``
prints ``name,us_per_call,derived`` CSV (+ ``# curve:`` blocks carrying the
convergence data each paper figure plots).
"""
import sys
import time

from benchmarks import (bench_averaging, bench_bits, bench_bits_accounting,
                        bench_extensions, bench_fedbuff, bench_kernels,
                        bench_local_steps, bench_peers, bench_quantizer,
                        bench_roofline, bench_swt, bench_time)

BENCHES = [
    ("Fig1_peers", bench_peers.main),
    ("Fig2_bits", bench_bits.main),
    ("Fig3_time", bench_time.main),
    ("Fig4_averaging", bench_averaging.main),
    ("Fig5_quantizer", bench_quantizer.main),
    ("Fig6_fedbuff", bench_fedbuff.main),
    ("Fig7_local_steps", bench_local_steps.main),
    ("Fig9_swt", bench_swt.main),
    ("Lemma38_bits", bench_bits_accounting.main),
    ("ext_scaffold_adaptive", bench_extensions.main),
    ("kernels", bench_kernels.main),
    ("roofline", bench_roofline.main),
]


def main() -> None:
    quick = "--quick" in sys.argv
    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        t0 = time.time()
        print(f"# === {name} ===")
        try:
            if fn.__code__.co_argcount and quick:
                fn(20)
            else:
                fn()
        except Exception as e:  # keep the harness going
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
