"""Benchmark harness entry point — one function per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--quick] [--only SUBSTR]``
prints ``name,us_per_call,derived`` CSV (+ ``# curve:`` blocks carrying the
convergence data each paper figure plots) and writes every emitted row to a
machine-readable JSON baseline so subsequent PRs have a perf trajectory to
diff against: the ``algorithms`` and ``population`` benches (the whole
registry under one clock; the population engine's scale/participation rows)
land in ``BENCH_algorithms.json``, everything else in
``BENCH_exchange.json``. ``--only`` filters benchmarks by name substring
(e.g. ``--only exchange``, ``--only population``); record names are the
baselines' merge keys, so duplicates across benches abort the run.
"""
import json
import os
import sys
import time

from benchmarks import (bench_algorithms, bench_analysis, bench_averaging,
                        bench_bits, bench_bits_accounting, bench_exchange,
                        bench_extensions, bench_fedbuff, bench_kernels,
                        bench_local_steps, bench_peers, bench_population,
                        bench_quantizer, bench_roofline, bench_swt,
                        bench_time)
from benchmarks.common import RECORDS

BENCHES = [
    ("Fig1_peers", bench_peers.main),
    ("Fig2_bits", bench_bits.main),
    ("Fig3_time", bench_time.main),
    ("Fig4_averaging", bench_averaging.main),
    ("Fig5_quantizer", bench_quantizer.main),
    ("Fig6_fedbuff", bench_fedbuff.main),
    ("Fig7_local_steps", bench_local_steps.main),
    ("Fig9_swt", bench_swt.main),
    ("Lemma38_bits", bench_bits_accounting.main),
    ("ext_scaffold_adaptive", bench_extensions.main),
    ("kernels", bench_kernels.main),
    ("exchange", bench_exchange.main),
    ("algorithms", bench_algorithms.main),
    ("population", bench_population.main),
    ("roofline", bench_roofline.main),
    ("analysis", bench_analysis.main),
]

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(_ROOT, "BENCH_exchange.json")
# benches whose records get their own baseline file (name -> path)
JSON_TARGETS = {"algorithms": os.path.join(_ROOT, "BENCH_algorithms.json"),
                "population": os.path.join(_ROOT, "BENCH_algorithms.json"),
                "analysis": os.path.join(_ROOT, "ANALYSIS.json")}
# quick-scale numbers are not comparable with the committed baselines, so
# they land under the gitignored bench_out/ instead of the repo root
QUICK_DIR = os.path.join(_ROOT, "bench_out")


def _arg_value(flag: str):
    if flag in sys.argv:
        i = sys.argv.index(flag)
        if i + 1 < len(sys.argv):
            return sys.argv[i + 1]
    return None


def _write_merged(path: str, records, quick: bool):
    """Merge records by name into ``path`` — a partial run (--only)
    refreshes its own rows without clobbering the committed baseline.
    Top-level keys beyond schema/quick/benches are preserved, so routing
    records into a richer report (ANALYSIS.json carries the full analyzer
    payload next to its bench rows) doesn't flatten it."""
    base = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                base = json.load(f)
        except ValueError:
            base = {}
    try:
        merged = {r["name"]: r for r in base.get("benches", [])}
    except (KeyError, TypeError):
        merged = {}
    merged.update({r["name"]: r for r in records})
    base.setdefault("schema", "bench.v1")
    base["quick"] = quick
    base["benches"] = list(merged.values())
    with open(path, "w") as f:
        json.dump(base, f, indent=2)
    print(f"# wrote {len(records)} records ({len(merged)} total) to {path}")


def main() -> None:
    quick = "--quick" in sys.argv
    only = _arg_value("--only")
    print("name,us_per_call,derived")
    by_target = {}   # json path -> records
    for name, fn in BENCHES:
        if only and only not in name:
            continue
        t0 = time.time()
        n_before = len(RECORDS)
        print(f"# === {name} ===")
        try:
            if fn.__code__.co_argcount and quick:
                fn(20)
            else:
                fn()
        except Exception as e:  # keep the harness going
            print(f"{name},0.0,ERROR={type(e).__name__}:{e}")
        target = JSON_TARGETS.get(name, JSON_PATH)
        by_target.setdefault(target, []).extend(RECORDS[n_before:])
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if not RECORDS:
        print("# no records emitted (bad --only filter?); leaving JSON "
              "baselines untouched")
        return
    # record names are the merge keys of the committed baselines: a
    # duplicate would silently overwrite another bench's row, so fail loud
    names = [r["name"] for r in RECORDS]
    dups = sorted({n for n in names if names.count(n) > 1})
    if dups:
        raise SystemExit(f"duplicate bench record names {dups}: two "
                         f"benches would clobber each other's baseline row")
    for path, records in by_target.items():
        if not records:
            continue
        if quick:
            os.makedirs(QUICK_DIR, exist_ok=True)
            path = os.path.join(
                QUICK_DIR,
                os.path.basename(path).replace(".json", ".quick.json"))
        _write_merged(path, records, quick)


if __name__ == "__main__":
    main()
