"""Paper Fig. 6 / 16: QuAFL (±lattice quantization) vs FedBuff (±QSGD) in
simulated time. FedBuff cannot use the lattice quantizer (no decoding key)."""
import jax

from repro.configs.base import FedConfig
from repro.core import FedBuff
from repro.models.mlp import mlp_loss
from benchmarks.common import (batch_fn, emit, emit_curve, run_quafl, setup)


def main(rounds: int = 100):
    # NON-IID (paper §4: 'QuAFL achieves better performance relative to
    # FedBuff in the non-i.i.d. case' — slow clients contribute less often
    # to FedBuff's buffer, skewing convergence toward fast clients' data)
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.4, bits=14,
                    swt=10.0, lam_slow=1.0 / 16)
    for quant, tag in (("lattice", "quafl_lattice"), ("none", "quafl_fp32")):
        f = FedConfig(**{**fed.__dict__, "quantizer": quant})
        r = run_quafl(f, rounds, iid=False, eval_every=rounds // 6)
        emit(tag, r["us_per_round"],
             f"acc={r['hist'][-1][3]:.3f};simt={r['hist'][-1][1]:.0f}")
        emit_curve(tag, r["hist"])
    total_time = rounds * (fed.swt + fed.sit)

    part, test, params0 = setup(fed, iid=False)
    for quantize, tag in ((False, "fedbuff_fp32"), (True, "fedbuff_qsgd")):
        alg = FedBuff(fed=fed, loss_fn=mlp_loss, template=params0,
                      batch_fn=batch_fn, buffer_size=4, server_lr=0.7,
                      quantize=quantize)
        hist = alg.run(params0, part, jax.random.PRNGKey(5),
                       total_time=total_time,
                       eval_every=total_time / 8,
                       eval_fn=lambda p: (float(mlp_loss(p, test)[0]),
                                          float(mlp_loss(p, test)[1]["acc"])))
        rows = [(i, t, l[0], l[1], b) for i, (t, l, b) in enumerate(hist)]
        emit(tag, 0.0, f"acc={rows[-1][3]:.3f};simt={rows[-1][1]:.0f}")
        emit_curve(tag, rows)


if __name__ == "__main__":
    main()
