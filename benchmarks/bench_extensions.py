"""Beyond-paper extensions (paper §5 future work): QuAFL-SCAFFOLD controlled
averaging (non-iid drift reduction) and the adaptive bit-width controller."""
import jax

from repro.configs.base import FedConfig
from repro.core import QuAFL
from repro.core.extensions import AdaptiveQuAFL, QuaflScaffold
from repro.models.mlp import init_mlp_classifier, mlp_loss
from benchmarks.common import batch_fn, emit, emit_curve, run_quafl, setup


def main(rounds: int = 80):
    fed = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=10,
                    swt=10.0)
    # vanilla vs SCAFFOLD on non-iid
    r = run_quafl(fed, rounds, iid=False, eval_every=rounds // 6)
    emit("ext_vanilla_noniid", r["us_per_round"],
         f"acc={r['hist'][-1][3]:.3f};loss={r['hist'][-1][2]:.3f}")
    emit_curve("ext_vanilla_noniid", r["hist"])

    part, test, params0 = setup(fed, iid=False)
    alg = QuaflScaffold(fed=fed, loss_fn=mlp_loss, template=params0,
                        batch_fn=batch_fn)
    st = alg.init(params0)
    key = jax.random.PRNGKey(1)
    hist = []
    for i in range(rounds):
        key, sub = jax.random.split(key)
        st, m = alg.round(st, part, sub)
        if (i + 1) % (rounds // 6) == 0:
            loss, metr = mlp_loss(alg.eval_params(st), test)
            hist.append((i + 1, float(st.base.sim_time), float(loss),
                         float(metr["acc"]), float(st.base.bits_sent)))
    emit("ext_scaffold_noniid", 0.0,
         f"acc={hist[-1][3]:.3f};loss={hist[-1][2]:.3f};"
         f"c_norm={float(m['c_norm']):.3f}")
    emit_curve("ext_scaffold_noniid", hist)

    # adaptive bits: starts at 12, should walk down while staying accurate
    feda = FedConfig(n_clients=16, s=4, local_steps=5, lr=0.3, bits=12,
                     swt=10.0)
    part, test, params0 = setup(feda, iid=True)
    wrap = AdaptiveQuAFL(
        feda, lambda f: QuAFL(fed=f, loss_fn=mlp_loss, template=params0,
                              batch_fn=batch_fn), params0)
    for i in range(rounds // 2):
        key, sub = jax.random.split(key)
        wrap.round(part, sub)
    loss, metr = mlp_loss(wrap.eval_params(), test)
    emit("ext_adaptive_bits", 0.0,
         f"acc={float(metr['acc']):.3f};bits_start=12;"
         f"bits_end={wrap.bits_trace[-1]};"
         f"trace={'/'.join(map(str, wrap.bits_trace[::5]))}")


if __name__ == "__main__":
    main()
