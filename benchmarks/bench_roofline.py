"""Render the roofline table (§Roofline of EXPERIMENTS.md) from the dry-run
JSON artifacts in experiments/dryrun/. Also usable as a module:
``python -m benchmarks.bench_roofline --md`` prints the markdown table."""
import glob
import json
import os
import sys

from benchmarks.common import emit


def load(out_dir: str = "experiments/dryrun"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def markdown_table(rows, mesh="single"):
    done = [r for r in rows if "roofline" in r
            and (mesh in ("all",) or r.get("mesh", {}) and
                 (("pod" in r["mesh"]) == (mesh == "multi")))]
    done.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL_FLOPS/HLO | bytes/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for r in done:
        t = r["roofline"]
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{t['bottleneck']} | "
            f"{ratio:.3f} | {r['bytes_per_device']:.3g} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - |")
    return "\n".join(lines)


def main():
    rows = load()
    ok = [r for r in rows if "roofline" in r]
    skipped = [r for r in rows if "skipped" in r]
    failed = [r for r in rows if "error" in r]
    emit("dryrun_pairs_ok", 0.0, f"count={len(ok)}")
    emit("dryrun_pairs_skipped", 0.0, f"count={len(skipped)}")
    emit("dryrun_pairs_failed", 0.0, f"count={len(failed)}")
    for r in failed:
        emit(f"FAILED_{r['arch']}_{r['shape']}", 0.0, r["error"][:80])
    if "--md" in sys.argv:
        print(markdown_table(rows, mesh="single"))
        print()
        print(markdown_table(rows, mesh="multi"))


if __name__ == "__main__":
    main()
