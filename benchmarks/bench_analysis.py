"""Static-analysis gate as a bench route: runs ``repro.analysis.lint``
over the full algorithm × codec (and codec × transport exchange) matrix
and emits one record per cell (analyzer wall time + violation count), so
the gate's cost and cleanliness ride the same baseline machinery as the
perf benches.

``python -m benchmarks.run --only analysis`` writes the full
machine-readable report to repo-root ``ANALYSIS.json`` (the harness then
merges the per-cell records into the same file, preserving the report's
top-level keys). Wall-clock timings live in the bench records and in
gitignored ``bench_out/analysis_timings.json``, never the committed
report — ANALYSIS.json is byte-deterministic.
"""
import json

from benchmarks.common import emit


def main(quick_rounds: int = 0) -> None:
    # the harness passes a round budget in --quick mode; the analysis gate
    # maps that to skipping the two expensive passes (donation compiles +
    # sentinel simulate() runs)
    from repro.analysis.lint import default_json_path, run_lint
    quick = bool(quick_rounds)
    timings = {}
    report = run_lint(quick=quick, verbose=False, timings=timings)
    for cell, rep in report["matrix"].items():
        n = len(rep.get("violations", []))
        eqns = rep.get("ops_round", {}).get("eqns_total", 0)
        emit(f"analysis_{cell}", timings.get(cell, 0.0) * 1e6,
             f"viols={n};round_eqns={eqns}")
    for cell, rep in report["exchange"].items():
        n = len(rep.get("violations", []))
        eqns = rep.get("ops", {}).get("eqns_total", 0)
        emit(f"analysis_{cell}", timings.get(cell, 0.0) * 1e6,
             f"viols={n};eqns={eqns}")
    for alg, rep in report["sentinel"].items():
        n = len(rep.get("violations", []))
        compiles = sum(rep.get("compiles", {}).values())
        emit(f"analysis_sentinel_{alg}",
             timings.get(f"sentinel:{alg}", 0.0) * 1e6,
             f"viols={n};compiles={compiles}")
    emit("analysis_ast", 0.0,
         f"viols={len(report['ast']['violations'])}")
    if not quick:
        # a quick report (no donation/sentinel passes) must not clobber
        # the committed full baseline at repo root
        path = default_json_path()
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {path} ({report['violations_total']} violations, "
              f"{timings.get('total', 0.0)}s)")


if __name__ == "__main__":
    main()
