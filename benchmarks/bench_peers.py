"""Paper Fig. 1 / 8 / 18: impact of the number of sampled peers s on
convergence (non-iid data, 30% slow clients)."""
from repro.configs.base import FedConfig
from benchmarks.common import emit, emit_curve, run_quafl


def main(rounds: int = 60):
    for s in (2, 4, 8):
        fed = FedConfig(n_clients=16, s=s, local_steps=5, lr=0.3, bits=14,
                        swt=10.0)
        r = run_quafl(fed, rounds, iid=False, eval_every=rounds // 6)
        final = r["hist"][-1]
        emit(f"peers_s{s}", r["us_per_round"],
             f"acc={final[3]:.3f};loss={final[2]:.3f}")
        emit_curve(f"peers_s{s}", r["hist"])


if __name__ == "__main__":
    main()
